//! Trace-driven execution simulator (paper §VI-C) — the evaluator used to
//! score model-chosen checkpointing intervals.
//!
//! Replays a malleable application over an execution segment
//! `[start, start+dur]` of a failure trace: at every (re)start the
//! rescheduling policy picks a subset of the currently functional
//! processors; the app accumulates checkpoint intervals (each followed by a
//! `C_a` checkpoint write) until one of its processors fails; work since
//! the last completed checkpoint is lost; recovery costs `R_{a1,a2}`; if
//! no processor is available the app waits for the first repair. Output is
//! the total useful work `UW` (and a timeline for Fig 5-style plots).
//!
//! ## Engine
//!
//! [`Simulator::new`] compiles the trace into a [`TraceIndex`] once;
//! [`Simulator::run`] then walks the merged event timeline with a
//! forward-only [`crate::traces::TraceCursor`], so every availability /
//! next-failure / next-repair query is an amortized O(1) cursor advance
//! with zero per-call allocation (the seed implementation re-ran
//! per-processor binary searches and allocated a fresh `Vec` at every
//! reconfiguration). [`Simulator::run_reference`] preserves the original
//! straight-from-trace implementation as the equivalence oracle — the
//! property suite asserts both produce identical [`SimResult`]s field for
//! field. [`Simulator::sweep_par`] fans a sweep out over the scoped thread
//! pool; the index is immutable and shared across workers.

use std::sync::OnceLock;

use crate::apps::AppProfile;
use crate::policies::ReschedulingPolicy;
use crate::traces::{EventCursor, FailureTrace, ShardedIndex, TraceIndex};
use crate::util::pool;
use anyhow::{bail, ensure, Result};

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Execution-segment start, seconds into the trace.
    pub start: f64,
    /// Segment duration, seconds.
    pub duration: f64,
    /// Checkpointing interval `I` under test.
    pub interval: f64,
    /// Override checkpoint cost (e.g. Fig 5's worst-case C = 20 min);
    /// `None` uses the profile's `C_a`.
    pub ckpt_override: Option<f64>,
    /// Override recovery cost similarly.
    pub rec_override: Option<f64>,
    /// Record a (time, active processors) timeline (Fig 5). Note that
    /// [`Simulator::sweep`] and [`Simulator::sweep_par`] force this off on
    /// their cloned configs — per-interval timelines are dead weight in
    /// large sweeps; use [`Simulator::sweep_with_timelines`] to keep them.
    pub record_timeline: bool,
    /// Pick the `a` processors with the fewest historical failures instead
    /// of the first available ones — the selection an availability-aware
    /// scheduler (AB policy) would make on a heterogeneous system
    /// (paper §IX extension).
    pub prefer_reliable: bool,
}

impl SimConfig {
    pub fn new(start: f64, duration: f64, interval: f64) -> SimConfig {
        SimConfig {
            start,
            duration,
            interval,
            ckpt_override: None,
            rec_override: None,
            record_timeline: false,
            prefer_reliable: false,
        }
    }
}

/// Simulation outcome.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SimResult {
    /// Total useful work (the paper's `UW`).
    pub useful_work: f64,
    /// Useful work per wall-clock second of the segment.
    pub uwt: f64,
    /// Seconds spent computing intervals that were later checkpointed.
    pub useful_seconds: f64,
    /// Seconds lost to checkpoint writes.
    pub ckpt_seconds: f64,
    /// Seconds lost to recovery/redistribution.
    pub recovery_seconds: f64,
    /// Seconds of computed-but-lost work (failure before checkpoint).
    pub lost_seconds: f64,
    /// Seconds with zero functional processors (waiting for repair).
    pub wait_seconds: f64,
    /// Number of failures that hit the application.
    pub failures: usize,
    /// Number of completed checkpoints.
    pub checkpoints: usize,
    /// (time, active processor count) step function, if requested.
    /// Consecutive identical entries are deduplicated.
    pub timeline: Vec<(f64, usize)>,
}

/// Append a timeline step, dropping consecutive identical `(t, a)` entries.
#[inline]
fn push_timeline(timeline: &mut Vec<(f64, usize)>, t: f64, a: usize) {
    if timeline.last() != Some(&(t, a)) {
        timeline.push((t, a));
    }
}

/// The trace-driven simulator.
pub struct Simulator<'a> {
    trace: &'a FailureTrace,
    app: &'a AppProfile,
    policy: &'a ReschedulingPolicy,
    /// Compiled lazily on the first indexed run, so reference-path users
    /// (and perf baselines) never pay for it; `OnceLock` keeps the
    /// simulator `Sync` for `sweep_par`.
    index: OnceLock<TraceIndex>,
}

impl<'a> Simulator<'a> {
    pub fn new(
        trace: &'a FailureTrace,
        app: &'a AppProfile,
        policy: &'a ReschedulingPolicy,
    ) -> Simulator<'a> {
        Simulator { trace, app, policy, index: OnceLock::new() }
    }

    /// The compiled event index (built on first use; shared by all runs
    /// and sweeps over this simulator).
    pub fn index(&self) -> &TraceIndex {
        self.index.get_or_init(|| TraceIndex::new(self.trace))
    }

    fn ckpt_cost(&self, cfg: &SimConfig, a: usize) -> f64 {
        cfg.ckpt_override.unwrap_or_else(|| self.app.checkpoint_cost(a))
    }

    fn rec_cost(&self, cfg: &SimConfig, from: usize, to: usize) -> f64 {
        cfg.rec_override.unwrap_or_else(|| self.app.recovery_cost(from, to))
    }

    fn validate(&self, cfg: &SimConfig) -> Result<f64> {
        if cfg.interval <= 0.0 || cfg.duration <= 0.0 || cfg.start < 0.0 {
            bail!("invalid simulation config: {cfg:?}");
        }
        let end = cfg.start + cfg.duration;
        if end > self.trace.horizon() {
            bail!(
                "segment [{}, {end}] exceeds trace horizon {}",
                cfg.start,
                self.trace.horizon()
            );
        }
        Ok(end)
    }

    /// Run one simulation on the compiled index.
    pub fn run(&self, cfg: &SimConfig) -> Result<SimResult> {
        let end = self.validate(cfg)?;
        self.run_with(self.index().cursor(self.trace), cfg, end)
    }

    /// Run one simulation on a time-window-sharded index
    /// ([`crate::traces::ShardedIndex`]) compiled from this simulator's
    /// trace: the identical walk as [`Simulator::run`] (same queries, same
    /// accounting, `SimResult` equal field for field — pinned by the
    /// equivalence suite), but only the shards the segment overlaps are
    /// ever decoded, which is what makes short segments over multi-year
    /// traces cheap.
    pub fn run_sharded(&self, index: &ShardedIndex, cfg: &SimConfig) -> Result<SimResult> {
        let end = self.validate(cfg)?;
        // Cheap identity guard (O(n), not O(E)): processor count, total
        // event count, and the exact bits of the last event time. The
        // cursor reads availability from the index but per-processor
        // failure queries from the trace, so a foreign index would give
        // silently wrong results rather than a crash.
        let trace_last = (0..self.trace.n_procs())
            .filter_map(|p| self.trace.outages(p).last().map(|&(_, r)| r))
            .fold(None::<f64>, |acc, r| Some(acc.map_or(r, |a| a.max(r))));
        ensure!(
            index.n_procs() == self.trace.n_procs()
                && index.n_events()
                    == 2 * (0..self.trace.n_procs())
                        .map(|p| self.trace.failure_count(p))
                        .sum::<usize>()
                && index.last_event_time().map(f64::to_bits) == trace_last.map(f64::to_bits),
            "sharded index was not compiled from this simulator's trace"
        );
        self.run_with(index.cursor(self.trace), cfg, end)
    }

    /// The indexed walk, generic over the cursor substrate (monolithic
    /// [`crate::traces::TraceCursor`] or sharded
    /// [`crate::traces::ShardedCursor`]).
    fn run_with<C: EventCursor>(&self, mut cur: C, cfg: &SimConfig, end: f64) -> Result<SimResult> {
        let mut r = SimResult::default();
        let mut active: Vec<usize> = Vec::with_capacity(self.trace.n_procs());

        let mut t = cfg.start;
        let mut prev_procs: Option<usize> = None;

        'outer: while t < end {
            // Pick a configuration from what is functional right now.
            let n_avail = cur.up_count(t);
            if n_avail == 0 {
                // Wait for the first repair.
                let wake = match cur.next_repair_total_outage(t) {
                    Some(w) => w.min(end),
                    None => end,
                };
                r.wait_seconds += wake - t;
                if cfg.record_timeline {
                    push_timeline(&mut r.timeline, t, 0);
                }
                t = wake;
                continue;
            }

            let a = self.policy.procs_for(n_avail);
            if cfg.prefer_reliable {
                // Rank by the failure-count prefix table (stable, so ties
                // keep processor-id order like the reference sort).
                cur.all_up(t, &mut active);
                let counts = cur.fail_counts(t);
                active.sort_by_key(|&p| counts[p]);
                active.truncate(a);
            } else {
                cur.first_up(t, a, &mut active);
            }
            if cfg.record_timeline {
                push_timeline(&mut r.timeline, t, a);
            }

            // Pay the redistribution/recovery cost (skipped at the very
            // first start, matching the paper's simulator which only
            // charges R on reconfiguration).
            if let Some(prev) = prev_procs {
                let rc = self.rec_cost(cfg, prev, a);
                let rec_end = (t + rc).min(end);
                // A failure of an active proc during recovery restarts the
                // reconfiguration decision.
                if let Some((ft, _)) = cur.next_failure_among(&active, t) {
                    if ft < rec_end {
                        r.recovery_seconds += ft - t;
                        r.failures += 1;
                        prev_procs = Some(a);
                        t = ft;
                        continue 'outer;
                    }
                }
                r.recovery_seconds += rec_end - t;
                t = rec_end;
                if t >= end {
                    break;
                }
            }
            prev_procs = Some(a);

            let rate = self.app.work_per_sec(a);
            let c = self.ckpt_cost(cfg, a);

            // Interval/checkpoint cycles until a failure or segment end.
            let next_fail = cur.next_failure_among(&active, t).map(|(ft, _)| ft);
            loop {
                let cycle_work_end = t + cfg.interval;
                let cycle_ckpt_end = cycle_work_end + c;

                let fail_now = match next_fail {
                    Some(ft) if ft < cycle_ckpt_end.min(end) => Some(ft),
                    _ => None,
                };

                if let Some(ft) = fail_now {
                    // Work since the last checkpoint is lost; time spent
                    // computing (or checkpointing) until ft is overhead.
                    let computed = (ft - t).min(cfg.interval).max(0.0);
                    r.lost_seconds += computed;
                    if ft > cycle_work_end {
                        // Failure hit during the checkpoint write.
                        r.ckpt_seconds += ft - cycle_work_end;
                    }
                    r.failures += 1;
                    t = ft;
                    continue 'outer;
                }

                if cycle_ckpt_end <= end {
                    // Completed interval + checkpoint: work is banked.
                    r.useful_seconds += cfg.interval;
                    r.useful_work += rate * cfg.interval;
                    r.ckpt_seconds += c;
                    r.checkpoints += 1;
                    t = cycle_ckpt_end;
                    if t >= end {
                        break 'outer;
                    }
                } else {
                    // Segment ends mid-cycle: uncheckpointed tail is lost
                    // (conservative, matches the paper's UW accounting of
                    // only checkpointed work... the tail has not been saved).
                    let computed = (end - t).min(cfg.interval).max(0.0);
                    r.lost_seconds += computed;
                    let into_ckpt = (end - t - cfg.interval).max(0.0);
                    r.ckpt_seconds += into_ckpt;
                    break 'outer;
                }
            }
        }

        r.uwt = r.useful_work / cfg.duration;
        Ok(r)
    }

    /// The seed implementation, querying the trace directly (per-processor
    /// binary searches, allocation per reconfiguration). Kept as the
    /// equivalence oracle for the indexed engine and as the perf-tracking
    /// baseline; numerically it performs the identical accounting in the
    /// identical order, so [`Simulator::run`] must reproduce its
    /// [`SimResult`] exactly.
    pub fn run_reference(&self, cfg: &SimConfig) -> Result<SimResult> {
        let end = self.validate(cfg)?;
        let mut r = SimResult::default();

        let mut t = cfg.start;
        let mut prev_procs: Option<usize> = None;

        'outer: while t < end {
            // Pick a configuration from what is functional right now.
            let avail = self.trace.available_at(t);
            if avail.is_empty() {
                // Wait for the first repair.
                let wake = match self.trace.next_repair_after(t) {
                    Some(w) => w.min(end),
                    None => end,
                };
                r.wait_seconds += wake - t;
                if cfg.record_timeline {
                    push_timeline(&mut r.timeline, t, 0);
                }
                t = wake;
                continue;
            }

            let a = self.policy.procs_for(avail.len());
            let active: Vec<usize> = if cfg.prefer_reliable {
                let mut ranked = avail.clone();
                ranked.sort_by_key(|&p| self.trace.failure_count_before(p, t));
                ranked[..a].to_vec()
            } else {
                avail[..a].to_vec()
            };
            if cfg.record_timeline {
                push_timeline(&mut r.timeline, t, a);
            }

            // Pay the redistribution/recovery cost (skipped at the very
            // first start, matching the paper's simulator which only
            // charges R on reconfiguration).
            if let Some(prev) = prev_procs {
                let rc = self.rec_cost(cfg, prev, a);
                let rec_end = (t + rc).min(end);
                // A failure of an active proc during recovery restarts the
                // reconfiguration decision.
                if let Some((ft, _)) = self.trace.next_failure_among(&active, t) {
                    if ft < rec_end {
                        r.recovery_seconds += ft - t;
                        r.failures += 1;
                        prev_procs = Some(a);
                        t = ft;
                        continue 'outer;
                    }
                }
                r.recovery_seconds += rec_end - t;
                t = rec_end;
                if t >= end {
                    break;
                }
            }
            prev_procs = Some(a);

            let rate = self.app.work_per_sec(a);
            let c = self.ckpt_cost(cfg, a);

            // Interval/checkpoint cycles until a failure or segment end.
            let next_fail = self.trace.next_failure_among(&active, t).map(|(ft, _)| ft);
            loop {
                let cycle_work_end = t + cfg.interval;
                let cycle_ckpt_end = cycle_work_end + c;

                let fail_now = match next_fail {
                    Some(ft) if ft < cycle_ckpt_end.min(end) => Some(ft),
                    _ => None,
                };

                if let Some(ft) = fail_now {
                    // Work since the last checkpoint is lost; time spent
                    // computing (or checkpointing) until ft is overhead.
                    let computed = (ft - t).min(cfg.interval).max(0.0);
                    r.lost_seconds += computed;
                    if ft > cycle_work_end {
                        // Failure hit during the checkpoint write.
                        r.ckpt_seconds += ft - cycle_work_end;
                    }
                    r.failures += 1;
                    t = ft;
                    continue 'outer;
                }

                if cycle_ckpt_end <= end {
                    // Completed interval + checkpoint: work is banked.
                    r.useful_seconds += cfg.interval;
                    r.useful_work += rate * cfg.interval;
                    r.ckpt_seconds += c;
                    r.checkpoints += 1;
                    t = cycle_ckpt_end;
                    if t >= end {
                        break 'outer;
                    }
                } else {
                    // Segment ends mid-cycle: uncheckpointed tail is lost
                    // (conservative, matches the paper's UW accounting of
                    // only checkpointed work... the tail has not been saved).
                    let computed = (end - t).min(cfg.interval).max(0.0);
                    r.lost_seconds += computed;
                    let into_ckpt = (end - t - cfg.interval).max(0.0);
                    r.ckpt_seconds += into_ckpt;
                    break 'outer;
                }
            }
        }

        r.uwt = r.useful_work / cfg.duration;
        Ok(r)
    }

    /// Sweep intervals and return `(interval, SimResult)` pairs — the
    /// paper's `UW_highest`/`I_sim` oracle sweep. Forces
    /// `record_timeline = false` on the per-interval configs; use
    /// [`Simulator::sweep_with_timelines`] if the timelines are wanted.
    pub fn sweep(&self, cfg_base: &SimConfig, intervals: &[f64]) -> Result<Vec<(f64, SimResult)>> {
        let mut base = cfg_base.clone();
        base.record_timeline = false;
        self.sweep_with_timelines(&base, intervals)
    }

    /// Sweep honoring `cfg_base.record_timeline` (opt-in; timelines are
    /// dead weight in large sweeps).
    pub fn sweep_with_timelines(
        &self,
        cfg_base: &SimConfig,
        intervals: &[f64],
    ) -> Result<Vec<(f64, SimResult)>> {
        intervals
            .iter()
            .map(|&i| {
                let mut cfg = cfg_base.clone();
                cfg.interval = i;
                Ok((i, self.run(&cfg)?))
            })
            .collect()
    }

    /// Parallel sweep over the scoped thread pool. Results are ordered by
    /// interval position and numerically identical to [`Simulator::sweep`]
    /// (each run is an independent deterministic walk of the shared
    /// index). Timelines are forced off, as in `sweep`.
    pub fn sweep_par(
        &self,
        cfg_base: &SimConfig,
        intervals: &[f64],
    ) -> Result<Vec<(f64, SimResult)>> {
        let mut base = cfg_base.clone();
        base.record_timeline = false;
        let workers = pool::default_workers().min(intervals.len().max(1));
        pool::run_indexed(intervals.len(), workers, |i| {
            let mut cfg = base.clone();
            cfg.interval = intervals[i];
            self.run(&cfg).map(|r| (intervals[i], r))
        })
        .into_iter()
        .collect()
    }

    /// [`Simulator::sweep_par`] over a shared [`ShardedIndex`]: every
    /// parallel run opens its own cursor on the one compiled index
    /// ([`Simulator::run_sharded`] per interval), so a sweep touches only
    /// the shards its segment overlaps and never recompiles the timeline.
    /// Numerically identical to [`Simulator::sweep`] — `run_sharded` is
    /// pinned field-for-field to `run`. Timelines forced off, as in
    /// `sweep`.
    pub fn sweep_par_sharded(
        &self,
        index: &ShardedIndex,
        cfg_base: &SimConfig,
        intervals: &[f64],
    ) -> Result<Vec<(f64, SimResult)>> {
        let mut base = cfg_base.clone();
        base.record_timeline = false;
        let workers = pool::default_workers().min(intervals.len().max(1));
        pool::run_indexed(intervals.len(), workers, |i| {
            let mut cfg = base.clone();
            cfg.interval = intervals[i];
            self.run_sharded(index, &cfg).map(|r| (intervals[i], r))
        })
        .into_iter()
        .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traces::synth::{generate, SynthSpec};
    use crate::util::rng::Rng;

    fn flat_app(n: usize) -> AppProfile {
        AppProfile::from_vectors(
            "flat",
            (1..=n).map(|a| a as f64).collect(),
            vec![10.0; n],
            5.0,
            5.0,
        )
        .unwrap()
    }

    #[test]
    fn failure_free_accounting_exact() {
        // No failures: duration splits into (I + C) cycles exactly.
        let trace = FailureTrace::new(vec![vec![], vec![]], 1.0e6).unwrap();
        let app = flat_app(2);
        let policy = ReschedulingPolicy::greedy(2);
        let sim = Simulator::new(&trace, &app, &policy);
        // 10 cycles of (90 + 10): useful 900 s at rate 2/s => UW 1800.
        let res = sim.run(&SimConfig::new(0.0, 1_000.0, 90.0)).unwrap();
        assert_eq!(res.checkpoints, 10);
        assert_eq!(res.failures, 0);
        assert!((res.useful_work - 1800.0).abs() < 1e-9);
        assert!((res.ckpt_seconds - 100.0).abs() < 1e-9);
        assert_eq!(res.wait_seconds, 0.0);
    }

    #[test]
    fn single_failure_loses_partial_interval() {
        // Proc fails at t=150 mid-second-interval: first cycle banked,
        // 50 s of computed work lost, then recovery + continue on proc 1.
        let trace = FailureTrace::new(vec![vec![(150.0, 1.0e5)], vec![]], 1.0e6).unwrap();
        let app = flat_app(2);
        let policy = ReschedulingPolicy::greedy(2);
        let sim = Simulator::new(&trace, &app, &policy);
        let res = sim.run(&SimConfig::new(0.0, 500.0, 90.0)).unwrap();
        assert_eq!(res.failures, 1);
        assert!(res.lost_seconds >= 49.0, "lost {}", res.lost_seconds);
        assert!(res.recovery_seconds > 0.0);
        // After failover it runs on 1 proc at rate 1.
        assert!(res.useful_work > 0.0);
    }

    #[test]
    fn zero_available_waits() {
        // Both procs down over [100, 300): app must wait.
        let trace = FailureTrace::new(
            vec![vec![(100.0, 300.0)], vec![(100.0, 300.0)]],
            1.0e4,
        )
        .unwrap();
        let app = flat_app(2);
        let policy = ReschedulingPolicy::greedy(2);
        let sim = Simulator::new(&trace, &app, &policy);
        let res = sim.run(&SimConfig::new(0.0, 1_000.0, 50.0)).unwrap();
        assert!(res.wait_seconds > 150.0, "wait {}", res.wait_seconds);
    }

    #[test]
    fn smaller_interval_more_checkpoints() {
        let mut rng = Rng::new(5);
        let trace = generate(
            &SynthSpec::exponential(8, 1.0 / (2.0 * 86_400.0), 1.0 / 3_600.0, 10.0 * 86_400.0),
            &mut rng,
        );
        let app = flat_app(8);
        let policy = ReschedulingPolicy::greedy(8);
        let sim = Simulator::new(&trace, &app, &policy);
        let small = sim.run(&SimConfig::new(0.0, 86_400.0, 600.0)).unwrap();
        let large = sim.run(&SimConfig::new(0.0, 86_400.0, 7_200.0)).unwrap();
        assert!(small.checkpoints > large.checkpoints);
    }

    #[test]
    fn interval_tradeoff_visible() {
        // With failures, both extremes lose to a moderate interval.
        let mut rng = Rng::new(6);
        let trace = generate(
            &SynthSpec::exponential(16, 1.0 / (6.0 * 3_600.0), 1.0 / 600.0, 40.0 * 86_400.0),
            &mut rng,
        );
        let app = flat_app(16);
        let policy = ReschedulingPolicy::greedy(16);
        let sim = Simulator::new(&trace, &app, &policy);
        // Aggregate MTBF is ~22 min (16 procs, 6 h MTTF each) with C = 10 s,
        // so the Young-style optimum sits near 300 s; both a 10 s and a
        // 1-day interval must lose to it.
        let cfg = SimConfig::new(0.0, 20.0 * 86_400.0, 1.0);
        let sweep = sim
            .sweep(&cfg, &[10.0, 300.0, 86_400.0])
            .unwrap()
            .into_iter()
            .map(|(_, r)| r.useful_work)
            .collect::<Vec<_>>();
        assert!(sweep[1] > sweep[0], "moderate {} !> tiny {}", sweep[1], sweep[0]);
        assert!(sweep[1] > sweep[2], "moderate {} !> huge {}", sweep[1], sweep[2]);
    }

    #[test]
    fn timeline_records_config_changes() {
        let trace = FailureTrace::new(vec![vec![(500.0, 2_000.0)], vec![]], 1.0e4).unwrap();
        let app = flat_app(2);
        let policy = ReschedulingPolicy::greedy(2);
        let sim = Simulator::new(&trace, &app, &policy);
        let mut cfg = SimConfig::new(0.0, 3_000.0, 100.0);
        cfg.record_timeline = true;
        let res = sim.run(&cfg).unwrap();
        assert!(res.timeline.len() >= 2);
        assert_eq!(res.timeline[0].1, 2);
        assert!(res.timeline.iter().any(|&(_, a)| a == 1));
    }

    #[test]
    fn timeline_has_no_consecutive_duplicates() {
        // A flapping processor produces many reconfigurations; the dedup
        // guarantees no two consecutive identical (t, a) entries survive.
        let mut flaps = Vec::new();
        let mut t = 10.0;
        while t < 4_000.0 {
            flaps.push((t, t + 1.0));
            t += 2.0;
        }
        let trace = FailureTrace::new(vec![vec![], flaps], 1.0e6).unwrap();
        let app = flat_app(2);
        let policy = ReschedulingPolicy::greedy(2);
        let sim = Simulator::new(&trace, &app, &policy);
        let mut cfg = SimConfig::new(0.0, 5_000.0, 50.0);
        cfg.record_timeline = true;
        let res = sim.run(&cfg).unwrap();
        for w in res.timeline.windows(2) {
            assert_ne!(w[0], w[1], "duplicate timeline entry {:?}", w[0]);
        }
    }

    #[test]
    fn sweep_drops_timelines_unless_opted_in() {
        let trace = FailureTrace::new(vec![vec![(500.0, 2_000.0)], vec![]], 1.0e4).unwrap();
        let app = flat_app(2);
        let policy = ReschedulingPolicy::greedy(2);
        let sim = Simulator::new(&trace, &app, &policy);
        let mut base = SimConfig::new(0.0, 3_000.0, 100.0);
        base.record_timeline = true; // sweeps must override this
        for (_, r) in sim.sweep(&base, &[50.0, 100.0]).unwrap() {
            assert!(r.timeline.is_empty(), "sweep kept a timeline");
        }
        for (_, r) in sim.sweep_par(&base, &[50.0, 100.0]).unwrap() {
            assert!(r.timeline.is_empty(), "sweep_par kept a timeline");
        }
        for (_, r) in sim.sweep_with_timelines(&base, &[50.0, 100.0]).unwrap() {
            assert!(!r.timeline.is_empty(), "opt-in sweep lost the timeline");
        }
    }

    #[test]
    fn sweep_par_matches_serial_sweep() {
        let mut rng = Rng::new(17);
        let trace = generate(
            &SynthSpec::exponential(12, 1.0 / 86_400.0, 1.0 / 1_800.0, 30.0 * 86_400.0),
            &mut rng,
        );
        let app = flat_app(12);
        let policy = ReschedulingPolicy::greedy(12);
        let sim = Simulator::new(&trace, &app, &policy);
        let cfg = SimConfig::new(86_400.0, 20.0 * 86_400.0, 1.0);
        let grid: Vec<f64> = (0..10).map(|i| 200.0 * (1.7f64).powi(i)).collect();
        let serial = sim.sweep(&cfg, &grid).unwrap();
        let par = sim.sweep_par(&cfg, &grid).unwrap();
        assert_eq!(serial.len(), par.len());
        for ((i1, r1), (i2, r2)) in serial.iter().zip(&par) {
            assert_eq!(i1, i2);
            assert_eq!(r1, r2, "sweep_par diverged at interval {i1}");
        }
    }

    #[test]
    fn indexed_run_matches_reference_smoke() {
        let mut rng = Rng::new(21);
        let trace = generate(
            &SynthSpec::exponential(10, 1.0 / (12.0 * 3_600.0), 1.0 / 900.0, 20.0 * 86_400.0),
            &mut rng,
        );
        let app = flat_app(10);
        let policy = ReschedulingPolicy::greedy(10);
        let sim = Simulator::new(&trace, &app, &policy);
        for prefer in [false, true] {
            let mut cfg = SimConfig::new(3_600.0, 10.0 * 86_400.0, 1_800.0);
            cfg.prefer_reliable = prefer;
            cfg.record_timeline = true;
            let fast = sim.run(&cfg).unwrap();
            let oracle = sim.run_reference(&cfg).unwrap();
            assert_eq!(fast, oracle, "indexed run diverged (prefer_reliable={prefer})");
        }
    }

    #[test]
    fn sharded_run_matches_indexed_run() {
        let mut rng = Rng::new(33);
        let trace = generate(
            &SynthSpec::exponential(10, 1.0 / (12.0 * 3_600.0), 1.0 / 900.0, 20.0 * 86_400.0),
            &mut rng,
        );
        let app = flat_app(10);
        let policy = ReschedulingPolicy::greedy(10);
        let sim = Simulator::new(&trace, &app, &policy);
        for window in [3_600.0, 86_400.0, 7.0 * 86_400.0, 1.0e9] {
            let sharded = ShardedIndex::new(&trace, window, 4).unwrap();
            for prefer in [false, true] {
                let mut cfg = SimConfig::new(3_600.0, 10.0 * 86_400.0, 1_800.0);
                cfg.prefer_reliable = prefer;
                cfg.record_timeline = true;
                let mono = sim.run(&cfg).unwrap();
                let shrd = sim.run_sharded(&sharded, &cfg).unwrap();
                assert_eq!(shrd, mono, "sharded run diverged (window {window}, prefer {prefer})");
            }
        }
        // An index from a different trace is rejected.
        let other = generate(
            &SynthSpec::exponential(10, 1.0 / 86_400.0, 1.0 / 900.0, 20.0 * 86_400.0),
            &mut Rng::new(34),
        );
        let foreign = ShardedIndex::new(&other, 86_400.0, 2).unwrap();
        assert!(sim.run_sharded(&foreign, &SimConfig::new(0.0, 86_400.0, 600.0)).is_err());
    }

    #[test]
    fn sharded_sweep_matches_serial_sweep() {
        let mut rng = Rng::new(35);
        let trace = generate(
            &SynthSpec::exponential(8, 1.0 / 86_400.0, 1.0 / 1_200.0, 25.0 * 86_400.0),
            &mut rng,
        );
        let app = flat_app(8);
        let policy = ReschedulingPolicy::greedy(8);
        let sim = Simulator::new(&trace, &app, &policy);
        let sharded = ShardedIndex::new(&trace, 2.0 * 86_400.0, 4).unwrap();
        let cfg = SimConfig::new(86_400.0, 15.0 * 86_400.0, 1.0);
        let grid: Vec<f64> = (0..9).map(|i| 300.0 * (2.0f64).powi(i)).collect();
        let serial = sim.sweep(&cfg, &grid).unwrap();
        let shrd = sim.sweep_par_sharded(&sharded, &cfg, &grid).unwrap();
        assert_eq!(serial.len(), shrd.len());
        for ((i1, r1), (i2, r2)) in serial.iter().zip(&shrd) {
            assert_eq!(i1, i2);
            assert_eq!(r1, r2, "sharded sweep diverged at interval {i1}");
        }
    }

    #[test]
    fn rejects_bad_configs() {
        let trace = FailureTrace::new(vec![vec![]], 100.0).unwrap();
        let app = flat_app(1);
        let policy = ReschedulingPolicy::greedy(1);
        let sim = Simulator::new(&trace, &app, &policy);
        assert!(sim.run(&SimConfig::new(0.0, 0.0, 10.0)).is_err());
        assert!(sim.run(&SimConfig::new(0.0, 10.0, 0.0)).is_err());
        assert!(sim.run(&SimConfig::new(0.0, 1_000.0, 10.0)).is_err()); // beyond horizon
    }

    #[test]
    fn work_conservation() {
        // useful + lost <= computing time <= duration.
        let mut rng = Rng::new(9);
        let trace = generate(
            &SynthSpec::exponential(4, 1.0 / 86_400.0, 1.0 / 1_800.0, 30.0 * 86_400.0),
            &mut rng,
        );
        let app = flat_app(4);
        let policy = ReschedulingPolicy::greedy(4);
        let sim = Simulator::new(&trace, &app, &policy);
        let cfg = SimConfig::new(86_400.0, 5.0 * 86_400.0, 3_600.0);
        let r = sim.run(&cfg).unwrap();
        let total = r.useful_seconds + r.lost_seconds + r.ckpt_seconds + r.recovery_seconds + r.wait_seconds;
        assert!(total <= cfg.duration * (1.0 + 1e-9), "total {total} > {}", cfg.duration);
        assert!(total > cfg.duration * 0.95, "unaccounted time: {total} vs {}", cfg.duration);
    }
}
