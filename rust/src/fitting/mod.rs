//! Curve fitting — the stand-in for the LAB Fit tool the paper uses to
//! extrapolate benchmarked overheads to larger processor counts (§VI-B).
//!
//! Provides ordinary least squares on arbitrary basis functions, plus the
//! two parametric families the application profiles need:
//!
//! * power law `y = c · x^p` (checkpoint/recovery cost growth), fitted in
//!   log space;
//! * Amdahl-like work rate `y = 1 / (t_serial + t_par/x + c_comm·x)`,
//!   fitted by least squares on the *reciprocal* (which is linear in the
//!   three coefficients).

use anyhow::{bail, Result};

/// Solve the normal equations `(XᵀX) β = Xᵀy` for a small design matrix
/// (column count ≤ ~4) via Gaussian elimination with partial pivoting.
pub fn least_squares(design: &[Vec<f64>], y: &[f64]) -> Result<Vec<f64>> {
    let n = design.len();
    if n == 0 || n != y.len() {
        bail!("design/observation size mismatch");
    }
    let k = design[0].len();
    if design.iter().any(|r| r.len() != k) {
        bail!("ragged design matrix");
    }
    if n < k {
        bail!("under-determined system: {n} rows, {k} coefficients");
    }

    // Normal equations.
    let mut ata = vec![vec![0.0f64; k]; k];
    let mut aty = vec![0.0f64; k];
    for (row, &yi) in design.iter().zip(y) {
        for i in 0..k {
            aty[i] += row[i] * yi;
            for j in 0..k {
                ata[i][j] += row[i] * row[j];
            }
        }
    }

    // Gaussian elimination with partial pivoting.
    for col in 0..k {
        let pivot = (col..k)
            .max_by(|&a, &b| ata[a][col].abs().partial_cmp(&ata[b][col].abs()).unwrap())
            .unwrap();
        if ata[pivot][col].abs() < 1e-12 {
            bail!("singular normal equations (collinear basis?)");
        }
        ata.swap(col, pivot);
        aty.swap(col, pivot);
        for row in (col + 1)..k {
            let f = ata[row][col] / ata[col][col];
            for j in col..k {
                ata[row][j] -= f * ata[col][j];
            }
            aty[row] -= f * aty[col];
        }
    }
    let mut beta = vec![0.0f64; k];
    for row in (0..k).rev() {
        let mut s = aty[row];
        for j in (row + 1)..k {
            s -= ata[row][j] * beta[j];
        }
        beta[row] = s / ata[row][row];
    }
    Ok(beta)
}

/// Power-law fit `y ≈ c · x^p` (log-space OLS). Returns `(c, p)`.
pub fn fit_power_law(x: &[f64], y: &[f64]) -> Result<(f64, f64)> {
    if x.len() != y.len() || x.len() < 2 {
        bail!("need at least two points");
    }
    if x.iter().chain(y).any(|&v| v <= 0.0) {
        bail!("power-law fit requires positive data");
    }
    let design: Vec<Vec<f64>> = x.iter().map(|&xi| vec![1.0, xi.ln()]).collect();
    let ly: Vec<f64> = y.iter().map(|&v| v.ln()).collect();
    let beta = least_squares(&design, &ly)?;
    Ok((beta[0].exp(), beta[1]))
}

/// Amdahl-communication model of parallel work rate. Work rate on `a`
/// processors: `rate(a) = 1 / (s + p/a + c·a)` — serial fraction `s`,
/// perfectly parallel work `p`, per-processor communication cost `c`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AmdahlFit {
    pub serial: f64,
    pub parallel: f64,
    pub comm: f64,
}

impl AmdahlFit {
    pub fn rate(&self, a: usize) -> f64 {
        let a = a as f64;
        1.0 / (self.serial + self.parallel / a + self.comm * a)
    }

    /// Processor count maximizing the rate (continuous optimum √(p/c),
    /// clamped to ≥ 1).
    pub fn optimal_procs(&self) -> f64 {
        if self.comm <= 0.0 {
            f64::INFINITY
        } else {
            (self.parallel / self.comm).sqrt().max(1.0)
        }
    }
}

/// Fit the Amdahl-communication model to (procs, rate) observations via
/// OLS on `1/rate = s + p/a + c·a`. Coefficients are clamped non-negative
/// (tiny negative values arise from noise).
pub fn fit_amdahl(procs: &[f64], rate: &[f64]) -> Result<AmdahlFit> {
    if procs.len() != rate.len() || procs.len() < 3 {
        bail!("need at least three points");
    }
    if procs.iter().chain(rate).any(|&v| v <= 0.0) {
        bail!("Amdahl fit requires positive data");
    }
    let design: Vec<Vec<f64>> = procs.iter().map(|&a| vec![1.0, 1.0 / a, a]).collect();
    let inv_rate: Vec<f64> = rate.iter().map(|&r| 1.0 / r).collect();
    let beta = least_squares(&design, &inv_rate)?;
    Ok(AmdahlFit {
        serial: beta[0].max(0.0),
        parallel: beta[1].max(1e-12),
        comm: beta[2].max(0.0),
    })
}

/// R² goodness of fit for predictions vs observations.
pub fn r_squared(y: &[f64], pred: &[f64]) -> f64 {
    let mean = y.iter().sum::<f64>() / y.len() as f64;
    let ss_tot: f64 = y.iter().map(|v| (v - mean) * (v - mean)).sum();
    let ss_res: f64 = y.iter().zip(pred).map(|(v, p)| (v - p) * (v - p)).sum();
    if ss_tot == 0.0 {
        1.0
    } else {
        1.0 - ss_res / ss_tot
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn ols_exact_line() {
        // y = 3 + 2x fitted exactly.
        let design: Vec<Vec<f64>> = (0..10).map(|i| vec![1.0, i as f64]).collect();
        let y: Vec<f64> = (0..10).map(|i| 3.0 + 2.0 * i as f64).collect();
        let beta = least_squares(&design, &y).unwrap();
        assert!((beta[0] - 3.0).abs() < 1e-10);
        assert!((beta[1] - 2.0).abs() < 1e-10);
    }

    #[test]
    fn power_law_recovers_exponent() {
        let x: Vec<f64> = vec![2.0, 4.0, 8.0, 16.0, 32.0, 48.0];
        let y: Vec<f64> = x.iter().map(|&v| 5.0 * v.powf(0.65)).collect();
        let (c, p) = fit_power_law(&x, &y).unwrap();
        assert!((c - 5.0).abs() < 1e-9);
        assert!((p - 0.65).abs() < 1e-10);
    }

    #[test]
    fn power_law_with_noise() {
        let mut rng = Rng::new(21);
        let x: Vec<f64> = (1..=24).map(|i| 2.0 * i as f64).collect();
        let y: Vec<f64> = x.iter().map(|&v| 3.0 * v.powf(0.5) * (1.0 + 0.05 * rng.normal(0.0, 1.0))).collect();
        let (c, p) = fit_power_law(&x, &y).unwrap();
        assert!((p - 0.5).abs() < 0.08, "p = {p}");
        assert!((c - 3.0).abs() / 3.0 < 0.15, "c = {c}");
    }

    #[test]
    fn amdahl_recovers_parameters() {
        let truth = AmdahlFit { serial: 0.02, parallel: 1.0, comm: 0.0005 };
        let procs: Vec<f64> = (1..=48).map(|a| a as f64).collect();
        let rate: Vec<f64> = procs.iter().map(|&a| truth.rate(a as usize)).collect();
        let fit = fit_amdahl(&procs, &rate).unwrap();
        assert!((fit.serial - truth.serial).abs() < 1e-8);
        assert!((fit.parallel - truth.parallel).abs() < 1e-7);
        assert!((fit.comm - truth.comm).abs() < 1e-9);
        // Extrapolation far beyond the data stays close.
        assert!((fit.rate(512) - truth.rate(512)).abs() / truth.rate(512) < 1e-6);
    }

    #[test]
    fn amdahl_optimum() {
        let f = AmdahlFit { serial: 0.0, parallel: 1.0, comm: 0.0001 };
        assert!((f.optimal_procs() - 100.0).abs() < 1e-9);
        // Rate indeed peaks near 100.
        assert!(f.rate(100) > f.rate(50));
        assert!(f.rate(100) > f.rate(200));
    }

    #[test]
    fn rejects_degenerate_inputs() {
        assert!(least_squares(&[], &[]).is_err());
        assert!(fit_power_law(&[1.0], &[2.0]).is_err());
        assert!(fit_power_law(&[1.0, -2.0], &[1.0, 2.0]).is_err());
        assert!(fit_amdahl(&[1.0, 2.0], &[1.0, 2.0]).is_err());
    }

    #[test]
    fn r_squared_perfect_and_poor() {
        let y = [1.0, 2.0, 3.0];
        assert!((r_squared(&y, &y) - 1.0).abs() < 1e-12);
        let bad = [3.0, 1.0, 2.0];
        assert!(r_squared(&y, &bad) < 0.5);
    }
}
