//! Batch-first selection facade — the one front door to the interval
//! search.
//!
//! The paper's workflow is inherently batch-shaped: §VI evaluates the
//! UWT model across many (system, application, policy) combinations and
//! "a large number of simulations". Before this module every surface
//! re-plumbed the same request by hand — the CLI called
//! [`crate::search::select_interval`], the advisor hand-rolled a
//! [`SharedBuilder`] per cache miss, experiments wired builders into
//! their segment loops — and nothing could amortize work *across*
//! requests. [`SelectSpec`] captures the full canonical request tuple
//! (system, app cost vectors, policy `rp` vector, search shape, build
//! options); [`SelectBatch`] validates every spec up front, **dedupes**
//! identical specs by [`SelectSpec::canonical_hash`] (one model build
//! answers all duplicates), fans the unique specs out over
//! [`crate::util::pool`] — one [`SharedBuilder`] per unique spec, π
//! warm-started across that spec's probes — and returns per-spec
//! [`SelectOutcome`]s **in input order** with per-item errors, so one
//! bad spec never poisons the batch.
//!
//! Every selection caller routes through here: CLI `select` (a one-spec
//! batch), the advisor's `/v1/select` and `/v1/select_batch` handlers,
//! the experiment sweeps ([`crate::experiments::common::run_segments`]),
//! and `benches/perf.rs`.
//!
//! ## Equivalence contract
//!
//! Batch results are pinned item-for-item to the singleton
//! [`crate::search::select_interval`] oracle (`rust/tests/
//! engine_equivalence.rs`): a cold [`SharedBuilder`] reproduces
//! `select_interval` bit for bit on the native engine, duplicates share
//! the representative's result (identical inputs give identical floats),
//! and `BuildOptions::workers` — the only knob the fan-out adjusts — is
//! pinned worker-invariant, which is also why [`canonical_hash`]
//! excludes it.

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::markov::{ModelInputs, SharedBuilder};
use crate::runtime::ComputeEngine;
use crate::search::{
    select_interval_shared_traced, select_interval_traced, SearchConfig, SearchResult, SearchTrace,
};
use crate::util::fnv::Fnv64;
use crate::util::pool;

/// Canonical hash of one selection request — the shared identity under
/// which the advisor cache keys entries and [`SelectBatch`] dedupes
/// specs. Hashes the semantic content: system triple, the three
/// per-processor-count cost vectors, the policy `rp` vector (not its
/// display name), the search shape and the result-affecting build
/// options. `BuildOptions::workers` is deliberately excluded: results
/// are pinned worker-invariant.
pub fn canonical_hash(inputs: &ModelInputs, cfg: &SearchConfig) -> u64 {
    let mut h = Fnv64::new();
    h.u64(0x4144_5631); // layout version tag ("ADV1")
    let n = inputs.system.n;
    h.u64(n as u64);
    h.f64(inputs.system.lambda);
    h.f64(inputs.system.theta);
    for a in 1..=n {
        h.f64(inputs.checkpoint_cost(a));
        h.f64(inputs.work_per_sec(a));
        h.f64(inputs.mean_recovery_into(a));
    }
    for &rp in inputs.policy.vector() {
        h.u64(rp as u64);
    }
    h.f64(cfg.i_min);
    h.f64(cfg.i_max);
    h.u64(cfg.refine_steps as u64);
    h.f64(cfg.band);
    match cfg.build.thres {
        Some(t) => {
            h.byte(1);
            h.f64(t);
        }
        None => h.byte(0),
    }
    h.byte(cfg.build.exact_probes as u8);
    h.f64(cfg.build.stationary.tol);
    h.u64(cfg.build.stationary.max_iters as u64);
    h.f64(cfg.build.stationary.damping);
    h.finish()
}

/// One fully specified selection request: everything that determines the
/// recommendation, and nothing that does not.
#[derive(Clone)]
pub struct SelectSpec {
    pub inputs: ModelInputs,
    pub cfg: SearchConfig,
}

impl SelectSpec {
    pub fn new(inputs: ModelInputs, cfg: SearchConfig) -> SelectSpec {
        SelectSpec { inputs, cfg }
    }

    /// The spec's canonical identity (see [`canonical_hash`]).
    pub fn canonical_hash(&self) -> u64 {
        canonical_hash(&self.inputs, &self.cfg)
    }

    /// Reject a spec whose search shape would degenerate the search —
    /// [`SelectBatch::run`] validates every spec up front so a bad item
    /// fails alone instead of deep inside a worker.
    pub fn validate(&self) -> Result<()> {
        self.cfg.validate()
    }
}

/// A failed batch item. Owns its message (rather than an
/// `anyhow::Error`) so duplicates of a failed spec can share the
/// representative's outcome like successful ones do.
#[derive(Debug, Clone)]
pub struct SelectError(pub String);

impl std::fmt::Display for SelectError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for SelectError {}

/// A successful batch item.
#[derive(Clone)]
pub struct SelectOk {
    /// The selection, identical to what the singleton
    /// [`crate::search::select_interval`] oracle returns for this spec.
    pub search: SearchResult,
    /// The probe-by-probe trajectory behind `search` (DESIGN.md §15) —
    /// what `/v1/explain` and `select --explain` render. Duplicates of
    /// one spec share the `Arc`.
    pub trace: Arc<SearchTrace>,
    /// The warm builder that ran the search (native engine only) —
    /// long-lived callers (the advisor cache) park it for O(1) repeats
    /// and warm-started refreshes. Duplicates of one spec share the
    /// `Arc`.
    pub builder: Option<Arc<SharedBuilder>>,
}

/// Per-spec result of [`SelectBatch::run`], in input order.
pub struct SelectOutcome {
    /// The spec's canonical hash (the dedup identity).
    pub key: u64,
    /// Input index of the representative spec whose search produced this
    /// outcome — equals the item's own index for unique specs, the first
    /// occurrence's index for duplicates.
    pub solved_by: usize,
    pub result: Result<SelectOk, SelectError>,
}

impl SelectOutcome {
    /// The selection, or the per-item error as `anyhow`.
    pub fn search(&self) -> Result<&SearchResult> {
        match &self.result {
            Ok(ok) => Ok(&ok.search),
            Err(e) => Err(anyhow!(e.clone())),
        }
    }
}

/// A batch of selection requests. Push specs in the order answers are
/// wanted; [`SelectBatch::run`] returns outcomes in that same order.
#[derive(Default)]
pub struct SelectBatch {
    specs: Vec<SelectSpec>,
}

impl SelectBatch {
    pub fn new() -> SelectBatch {
        SelectBatch::default()
    }

    pub fn from_specs(specs: Vec<SelectSpec>) -> SelectBatch {
        SelectBatch { specs }
    }

    /// Append a spec; returns its batch index.
    pub fn push(&mut self, spec: SelectSpec) -> usize {
        self.specs.push(spec);
        self.specs.len() - 1
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// Run the batch with the pool's default fan-out width, retaining
    /// each unique spec's builder in its outcome (the advisor parks
    /// them in its cache).
    pub fn run(&self, engine: &ComputeEngine) -> Vec<SelectOutcome> {
        self.run_with_workers(engine, pool::default_workers())
    }

    /// Like [`SelectBatch::run`], but drops each unique spec's builder
    /// the moment its search completes (`SelectOk::builder` is `None`
    /// for every outcome). Sweep-style callers that keep only the
    /// `SearchResult`s — [`crate::experiments::common::run_segments`] —
    /// use this so peak builder memory stays with the
    /// `min(workers, unique specs)` *concurrent* builds instead of one
    /// retained builder per unique spec (~0.5 GB each at N = 512).
    pub fn run_discarding_builders(&self, engine: &ComputeEngine) -> Vec<SelectOutcome> {
        self.execute(engine, pool::default_workers(), false)
    }

    /// Run the batch: validate every spec, dedupe by canonical hash, fan
    /// the unique specs out over at most `workers` threads (native
    /// engines; PJRT engines are thread-affine and evaluate serially),
    /// and return per-spec outcomes in input order. Each unique spec's
    /// fan-out share of the worker budget goes to its builder
    /// (`BuildOptions::workers` is divided, never multiplied — results
    /// are pinned worker-invariant, so only scheduling changes).
    pub fn run_with_workers(&self, engine: &ComputeEngine, workers: usize) -> Vec<SelectOutcome> {
        self.execute(engine, workers, true)
    }

    fn execute(
        &self,
        engine: &ComputeEngine,
        workers: usize,
        keep_builders: bool,
    ) -> Vec<SelectOutcome> {
        let n = self.specs.len();
        let keys: Vec<u64> = self.specs.iter().map(SelectSpec::canonical_hash).collect();
        let mut invalid: Vec<Option<SelectError>> = self
            .specs
            .iter()
            .map(|s| s.validate().err().map(|e| SelectError(format!("{e:#}"))))
            .collect();

        // Dedup: the first valid occurrence of each key represents it.
        let mut slot_of: HashMap<u64, usize> = HashMap::new();
        let mut uniques: Vec<usize> = Vec::new();
        for i in 0..n {
            if invalid[i].is_none() {
                if let Entry::Vacant(slot) = slot_of.entry(keys[i]) {
                    slot.insert(uniques.len());
                    uniques.push(i);
                }
            }
        }

        let fan = workers.max(1).min(uniques.len().max(1));
        let solved: Vec<Result<SelectOk, SelectError>> = match engine {
            ComputeEngine::Native => pool::run_indexed(uniques.len(), fan, |u| {
                let spec = &self.specs[uniques[u]];
                let mut cfg = spec.cfg;
                cfg.build.workers = (cfg.build.workers / fan).max(1);
                let builder = Arc::new(SharedBuilder::native(spec.inputs.clone(), &cfg.build));
                match select_interval_shared_traced(&builder, &cfg) {
                    // Without `keep_builders` the Arc drops right here,
                    // as this task ends — not after the whole batch.
                    Ok((search, trace)) => Ok(SelectOk {
                        search,
                        trace: Arc::new(trace),
                        builder: keep_builders.then_some(builder),
                    }),
                    Err(e) => Err(SelectError(format!("{e:#}"))),
                }
            }),
            ComputeEngine::NativeGeneric => pool::run_indexed(uniques.len(), fan, |u| {
                // The generic engine is zero-state: each task gets its
                // own handle (the paper-faithful expm path has no shared
                // builder to keep).
                let spec = &self.specs[uniques[u]];
                let mut cfg = spec.cfg;
                cfg.build.workers = (cfg.build.workers / fan).max(1);
                let engine = ComputeEngine::native_generic();
                match select_interval_traced(&spec.inputs, &engine, &cfg) {
                    Ok((search, trace)) => {
                        Ok(SelectOk { search, trace: Arc::new(trace), builder: None })
                    }
                    Err(e) => Err(SelectError(format!("{e:#}"))),
                }
            }),
            _ => uniques
                .iter()
                .map(|&i| {
                    let spec = &self.specs[i];
                    match select_interval_traced(&spec.inputs, engine, &spec.cfg) {
                        Ok((search, trace)) => {
                            Ok(SelectOk { search, trace: Arc::new(trace), builder: None })
                        }
                        Err(e) => Err(SelectError(format!("{e:#}"))),
                    }
                })
                .collect(),
        };

        (0..n)
            .map(|i| match invalid[i].take() {
                Some(err) => SelectOutcome { key: keys[i], solved_by: i, result: Err(err) },
                None => {
                    let slot = slot_of[&keys[i]];
                    SelectOutcome {
                        key: keys[i],
                        solved_by: uniques[slot],
                        result: solved[slot].clone(),
                    }
                }
            })
            .collect()
    }
}

/// The facade's singleton path — a one-spec batch. CLI `select`, the
/// advisor's `/v1/select` miss path and per-segment evaluations resolve
/// through this, so every selection in the system shares one engine
/// dispatch.
pub fn select_one(spec: SelectSpec, engine: &ComputeEngine) -> Result<SelectOk> {
    let mut outcomes = SelectBatch::from_specs(vec![spec]).run(engine);
    outcomes
        .pop()
        .expect("a one-spec batch yields one outcome")
        .result
        .map_err(|e| anyhow!(e))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemParams;
    use crate::policies::ReschedulingPolicy;

    fn inputs(n: usize, mttf_days: f64) -> ModelInputs {
        let system = SystemParams::from_mttf_mttr(n, mttf_days, 45.0);
        ModelInputs::from_raw(
            system,
            vec![60.0; n],
            (1..=n).map(|a| (a as f64).powf(0.85)).collect(),
            vec![15.0; n],
            ReschedulingPolicy::greedy(n),
        )
        .unwrap()
    }

    fn quick_cfg() -> SearchConfig {
        SearchConfig { refine_steps: 2, ..Default::default() }
    }

    #[test]
    fn canonical_hash_matches_cache_key() {
        // One definition: the advisor cache keys and the batch dedup must
        // agree forever (persisted SpecRecords carry these hashes).
        let cfg = quick_cfg();
        let spec = SelectSpec::new(inputs(5, 3.0), cfg);
        assert_eq!(
            spec.canonical_hash(),
            crate::advisor::cache::canonical_key(&inputs(5, 3.0), &cfg)
        );
    }

    #[test]
    fn one_spec_batch_matches_select_interval() {
        let engine = ComputeEngine::native();
        let cfg = quick_cfg();
        let oracle = select_interval(&inputs(6, 2.0), &engine, &cfg).unwrap();
        let got = select_one(SelectSpec::new(inputs(6, 2.0), cfg), &engine).unwrap();
        assert_eq!(got.search.probes, oracle.probes);
        assert_eq!(got.search.interval, oracle.interval);
        assert_eq!(got.search.uwt, oracle.uwt);
        assert!(got.builder.is_some(), "native path must return the builder");
    }

    #[test]
    fn dedup_builds_once_and_preserves_input_order() {
        let engine = ComputeEngine::native();
        let cfg = quick_cfg();
        // Indices 0, 2, 3 are the same spec; 1 and 4 are distinct.
        let batch = SelectBatch::from_specs(vec![
            SelectSpec::new(inputs(5, 2.0), cfg),
            SelectSpec::new(inputs(5, 6.0), cfg),
            SelectSpec::new(inputs(5, 2.0), cfg),
            SelectSpec::new(inputs(5, 2.0), cfg),
            SelectSpec::new(inputs(6, 2.0), cfg),
        ]);
        let out = batch.run(&engine);
        assert_eq!(out.len(), 5);
        assert_eq!(out[0].key, out[2].key);
        assert_eq!(out[0].key, out[3].key);
        assert_ne!(out[0].key, out[1].key);
        assert_ne!(out[0].key, out[4].key);
        // Duplicates are answered by item 0's single build: same
        // representative, the same builder instance, identical floats.
        for i in [2usize, 3] {
            assert_eq!(out[i].solved_by, 0, "duplicate {i} not deduped");
            let (a, b) = (out[0].result.as_ref().unwrap(), out[i].result.as_ref().unwrap());
            assert!(
                Arc::ptr_eq(a.builder.as_ref().unwrap(), b.builder.as_ref().unwrap()),
                "duplicates must share one SharedBuilder"
            );
            assert_eq!(a.search.probes, b.search.probes);
            assert_eq!(a.search.interval, b.search.interval);
        }
        assert_eq!(out[1].solved_by, 1);
        assert_eq!(out[4].solved_by, 4);
        // Order: every outcome pinned to its own spec's oracle.
        for (i, mttf, n) in [(0usize, 2.0, 5usize), (1, 6.0, 5), (4, 2.0, 6)] {
            let oracle = select_interval(&inputs(n, mttf), &engine, &cfg).unwrap();
            let got = out[i].search().unwrap();
            assert_eq!(got.interval, oracle.interval, "item {i} out of order");
            assert_eq!(got.probes, oracle.probes);
        }
    }

    #[test]
    fn per_item_error_is_isolated() {
        let engine = ComputeEngine::native();
        let bad_cfg = SearchConfig { i_min: -5.0, ..quick_cfg() };
        let batch = SelectBatch::from_specs(vec![
            SelectSpec::new(inputs(5, 2.0), quick_cfg()),
            SelectSpec::new(inputs(5, 2.0), bad_cfg),
            SelectSpec::new(inputs(5, 4.0), quick_cfg()),
        ]);
        let out = batch.run(&engine);
        assert!(out[0].result.is_ok(), "valid item poisoned by a bad sibling");
        assert!(out[2].result.is_ok());
        let err = out[1].result.as_ref().unwrap_err();
        assert!(err.0.contains("i_min"), "error should name the bad field: {err}");
        assert_eq!(out[1].solved_by, 1, "an invalid item is its own representative");
    }

    #[test]
    fn generic_engine_batch_matches_its_oracle() {
        let engine = ComputeEngine::native_generic();
        let cfg = SearchConfig { refine_steps: 1, ..Default::default() };
        let oracle = select_interval(&inputs(4, 3.0), &engine, &cfg).unwrap();
        let out = SelectBatch::from_specs(vec![SelectSpec::new(inputs(4, 3.0), cfg)]).run(&engine);
        let got = out[0].search().unwrap();
        assert_eq!(got.interval, oracle.interval);
        assert_eq!(got.probes, oracle.probes);
        assert!(out[0].result.as_ref().unwrap().builder.is_none());
    }

    #[test]
    fn discarding_run_matches_but_keeps_no_builders() {
        let engine = ComputeEngine::native();
        let cfg = quick_cfg();
        let specs =
            vec![SelectSpec::new(inputs(5, 2.0), cfg), SelectSpec::new(inputs(5, 6.0), cfg)];
        let kept = SelectBatch::from_specs(specs.clone()).run(&engine);
        let lean = SelectBatch::from_specs(specs).run_discarding_builders(&engine);
        for (a, b) in kept.iter().zip(&lean) {
            assert_eq!(a.key, b.key);
            let (a, b) = (a.result.as_ref().unwrap(), b.result.as_ref().unwrap());
            assert!(a.builder.is_some());
            assert!(b.builder.is_none(), "discarding run must not retain builders");
            assert_eq!(a.search.probes, b.search.probes);
            assert_eq!(a.search.interval, b.search.interval);
            assert_eq!(a.search.uwt, b.search.uwt);
        }
    }

    #[test]
    fn empty_batch_is_empty() {
        assert!(SelectBatch::new().run(&ComputeEngine::native()).is_empty());
        assert!(SelectBatch::new().is_empty());
    }
}
