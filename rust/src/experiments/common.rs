//! Shared experiment plumbing: segment sampling, trace construction from
//! the paper's published system rows, report tables.

use crate::apps::AppProfile;
use crate::config::SystemParams;
use crate::metrics::{evaluate_segment, evaluate_segment_reference, AggregateEvaluation, SegmentEvaluation};
use crate::policies::ReschedulingPolicy;
use crate::runtime::ComputeEngine;
use crate::search::SearchConfig;
use crate::traces::synth::{generate, SynthSpec};
use crate::traces::FailureTrace;
use crate::util::pool;
use crate::util::rng::Rng;
use anyhow::Result;

/// Knobs shared by all experiments (scaled down by default so the full
/// suite completes on a laptop-class box; the paper's "large number of
/// simulations" corresponds to raising `segments`).
#[derive(Debug, Clone)]
pub struct ExperimentOptions {
    /// Random execution segments per table row.
    pub segments: usize,
    /// Segment duration range, days.
    pub dur_days: (f64, f64),
    /// Trace length, days.
    pub trace_days: f64,
    /// Base RNG seed (every experiment derives from it).
    pub seed: u64,
    /// Interval-search configuration.
    pub search: SearchConfig,
}

impl Default for ExperimentOptions {
    fn default() -> Self {
        ExperimentOptions {
            segments: 3,
            dur_days: (10.0, 25.0),
            trace_days: 160.0,
            seed: 20_170_611,
            search: SearchConfig { refine_steps: 2, ..Default::default() },
        }
    }
}

/// Synthesize the paper's trace for a published system row
/// (DESIGN.md §6 substitution).
pub fn trace_for_system(sys: &SystemParams, days: f64, rng: &mut Rng) -> FailureTrace {
    generate(
        &SynthSpec::exponential(sys.n, sys.lambda, sys.theta, days * 86_400.0),
        rng,
    )
}

/// Draw the `(start, duration)` of every random segment up front, in the
/// exact order the seed's serial loop consumed the RNG — pre-drawing is
/// what lets the evaluations run in parallel without changing any result.
fn segment_params(trace: &FailureTrace, opts: &ExperimentOptions, rng: &mut Rng) -> Vec<(f64, f64)> {
    (0..opts.segments)
        .map(|_| {
            let dur = rng.range(opts.dur_days.0, opts.dur_days.1) * 86_400.0;
            let latest = (trace.horizon() - dur).max(0.0);
            // Leave some history before the segment for rate estimation.
            let start = rng.range(0.2 * latest, latest);
            (start, dur)
        })
        .collect()
}

/// Run `segments` random-segment evaluations of (trace, app, policy),
/// fanned out over the scoped thread pool (segments are independent; the
/// RNG draws are made serially first, so results are identical to the
/// seed's serial loop). PJRT engines are thread-affine and evaluate
/// serially.
///
/// Memory note: each concurrent segment holds its own `ModelBuilder`
/// caches for the duration of its interval search, so peak memory scales
/// with `min(workers, segments)` — ~0.5 GB per concurrent segment at
/// N = 512 (see `markov::builder`). Lower `opts.segments` or run the
/// serial [`run_segments_reference`] on memory-constrained machines.
pub fn run_segments(
    trace: &FailureTrace,
    app: &AppProfile,
    policy: &ReschedulingPolicy,
    engine: &ComputeEngine,
    sys: &SystemParams,
    opts: &ExperimentOptions,
    rng: &mut Rng,
) -> Result<AggregateEvaluation> {
    let params = segment_params(trace, opts, rng);
    let workers = pool::default_workers().min(params.len().max(1));
    let fallback = Some((sys.lambda, sys.theta));
    let evals: Vec<Result<SegmentEvaluation>> = if engine.is_native() && workers > 1 {
        // Hand each worker its own (zero-state) native engine handle: the
        // engine value itself must not cross threads when it is PJRT.
        let generic = matches!(*engine, ComputeEngine::NativeGeneric);
        // Split the caller's worker budget between the segment fan-out and
        // each segment's inner model-build pool instead of multiplying
        // them (worker count affects scheduling only, never results).
        let mut search_cfg = opts.search;
        search_cfg.build.workers = (opts.search.build.workers / workers).max(1);
        pool::map_slice(&params, workers, |&(start, dur)| {
            let engine = if generic {
                ComputeEngine::native_generic()
            } else {
                ComputeEngine::native()
            };
            evaluate_segment(trace, app, policy, &engine, start, dur, &search_cfg, fallback)
        })
    } else {
        params
            .iter()
            .map(|&(start, dur)| {
                evaluate_segment(trace, app, policy, engine, start, dur, &opts.search, fallback)
            })
            .collect()
    };
    let mut agg = AggregateEvaluation::default();
    for eval in evals {
        agg.segments.push(eval?);
    }
    Ok(agg)
}

/// The seed's serial path over the same pre-drawn segments, evaluated
/// through [`evaluate_segment_reference`] — the end-to-end baseline for
/// `benches/perf.rs` and the equivalence suite. Consumes the RNG exactly
/// like [`run_segments`].
pub fn run_segments_reference(
    trace: &FailureTrace,
    app: &AppProfile,
    policy: &ReschedulingPolicy,
    engine: &ComputeEngine,
    sys: &SystemParams,
    opts: &ExperimentOptions,
    rng: &mut Rng,
) -> Result<AggregateEvaluation> {
    let params = segment_params(trace, opts, rng);
    let mut agg = AggregateEvaluation::default();
    for &(start, dur) in &params {
        let eval = evaluate_segment_reference(
            trace,
            app,
            policy,
            engine,
            start,
            dur,
            &opts.search,
            Some((sys.lambda, sys.theta)),
        )?;
        agg.segments.push(eval);
    }
    Ok(agg)
}

/// Fixed-width table printer for experiment output.
pub struct TablePrinter {
    widths: Vec<usize>,
}

impl TablePrinter {
    pub fn new(headers: &[&str], widths: &[usize]) -> TablePrinter {
        let t = TablePrinter { widths: widths.to_vec() };
        t.row(headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        t
    }

    pub fn row(&self, cells: &[&str]) {
        let mut line = String::new();
        for (cell, w) in cells.iter().zip(&self.widths) {
            line.push_str(&format!("{cell:>w$}  ", w = w));
        }
        println!("{}", line.trim_end());
    }
}
