//! Shared experiment plumbing: segment sampling, trace construction from
//! the paper's published system rows, report tables.

use crate::apps::AppProfile;
use crate::config::SystemParams;
use crate::metrics::{evaluate_segment, AggregateEvaluation};
use crate::policies::ReschedulingPolicy;
use crate::runtime::ComputeEngine;
use crate::search::SearchConfig;
use crate::traces::synth::{generate, SynthSpec};
use crate::traces::FailureTrace;
use crate::util::rng::Rng;
use anyhow::Result;

/// Knobs shared by all experiments (scaled down by default so the full
/// suite completes on a laptop-class box; the paper's "large number of
/// simulations" corresponds to raising `segments`).
#[derive(Debug, Clone)]
pub struct ExperimentOptions {
    /// Random execution segments per table row.
    pub segments: usize,
    /// Segment duration range, days.
    pub dur_days: (f64, f64),
    /// Trace length, days.
    pub trace_days: f64,
    /// Base RNG seed (every experiment derives from it).
    pub seed: u64,
    /// Interval-search configuration.
    pub search: SearchConfig,
}

impl Default for ExperimentOptions {
    fn default() -> Self {
        ExperimentOptions {
            segments: 3,
            dur_days: (10.0, 25.0),
            trace_days: 160.0,
            seed: 20_170_611,
            search: SearchConfig { refine_steps: 2, ..Default::default() },
        }
    }
}

/// Synthesize the paper's trace for a published system row
/// (DESIGN.md §6 substitution).
pub fn trace_for_system(sys: &SystemParams, days: f64, rng: &mut Rng) -> FailureTrace {
    generate(
        &SynthSpec::exponential(sys.n, sys.lambda, sys.theta, days * 86_400.0),
        rng,
    )
}

/// Run `segments` random-segment evaluations of (trace, app, policy).
pub fn run_segments(
    trace: &FailureTrace,
    app: &AppProfile,
    policy: &ReschedulingPolicy,
    engine: &ComputeEngine,
    sys: &SystemParams,
    opts: &ExperimentOptions,
    rng: &mut Rng,
) -> Result<AggregateEvaluation> {
    let mut agg = AggregateEvaluation::default();
    for _ in 0..opts.segments {
        let dur = rng.range(opts.dur_days.0, opts.dur_days.1) * 86_400.0;
        let latest = (trace.horizon() - dur).max(0.0);
        // Leave some history before the segment for rate estimation.
        let start = rng.range(0.2 * latest, latest);
        let eval = evaluate_segment(
            trace,
            app,
            policy,
            engine,
            start,
            dur,
            &opts.search,
            Some((sys.lambda, sys.theta)),
        )?;
        agg.segments.push(eval);
    }
    Ok(agg)
}

/// Fixed-width table printer for experiment output.
pub struct TablePrinter {
    widths: Vec<usize>,
}

impl TablePrinter {
    pub fn new(headers: &[&str], widths: &[usize]) -> TablePrinter {
        let t = TablePrinter { widths: widths.to_vec() };
        t.row(headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        t
    }

    pub fn row(&self, cells: &[&str]) {
        let mut line = String::new();
        for (cell, w) in cells.iter().zip(&self.widths) {
            line.push_str(&format!("{cell:>w$}  ", w = w));
        }
        println!("{}", line.trim_end());
    }
}
