//! Shared experiment plumbing: segment sampling, trace construction from
//! the paper's published system rows, report tables.

use crate::api::{SelectBatch, SelectSpec};
use crate::apps::AppProfile;
use crate::config::SystemParams;
use crate::markov::ModelInputs;
use crate::metrics::{
    evaluate_segment_reference, evaluate_segment_simulated, segment_rates, AggregateEvaluation,
    SegmentEvaluation,
};
use crate::policies::ReschedulingPolicy;
use crate::runtime::ComputeEngine;
use crate::search::{SearchConfig, SearchResult};
use crate::traces::synth::{generate, SynthSpec};
use crate::traces::{FailureTrace, ShardedIndex};
use crate::util::pool;
use crate::util::rng::Rng;
use anyhow::Result;

/// Knobs shared by all experiments (scaled down by default so the full
/// suite completes on a laptop-class box; the paper's "large number of
/// simulations" corresponds to raising `segments`).
#[derive(Debug, Clone)]
pub struct ExperimentOptions {
    /// Random execution segments per table row.
    pub segments: usize,
    /// Segment duration range, days.
    pub dur_days: (f64, f64),
    /// Trace length, days.
    pub trace_days: f64,
    /// Base RNG seed (every experiment derives from it).
    pub seed: u64,
    /// Interval-search configuration.
    pub search: SearchConfig,
    /// Time-window width of the shared [`ShardedIndex`] segment
    /// evaluations run over, days (see [`run_segments`]).
    pub shard_window_days: f64,
}

impl Default for ExperimentOptions {
    fn default() -> Self {
        ExperimentOptions {
            segments: 3,
            dur_days: (10.0, 25.0),
            trace_days: 160.0,
            seed: 20_170_611,
            search: SearchConfig { refine_steps: 2, ..Default::default() },
            shard_window_days: 7.0,
        }
    }
}

/// Synthesize the paper's trace for a published system row
/// (DESIGN.md §6 substitution).
pub fn trace_for_system(sys: &SystemParams, days: f64, rng: &mut Rng) -> FailureTrace {
    generate(
        &SynthSpec::exponential(sys.n, sys.lambda, sys.theta, days * 86_400.0),
        rng,
    )
}

/// Draw the `(start, duration)` of every random segment up front, in the
/// exact order the seed's serial loop consumed the RNG — pre-drawing is
/// what lets the evaluations run in parallel without changing any result.
fn segment_params(trace: &FailureTrace, opts: &ExperimentOptions, rng: &mut Rng) -> Vec<(f64, f64)> {
    (0..opts.segments)
        .map(|_| {
            let dur = rng.range(opts.dur_days.0, opts.dur_days.1) * 86_400.0;
            let latest = (trace.horizon() - dur).max(0.0);
            // Leave some history before the segment for rate estimation.
            let start = rng.range(0.2 * latest, latest);
            (start, dur)
        })
        .collect()
}

/// Run `segments` random-segment evaluations of (trace, app, policy) —
/// batch-first, in three phases:
///
/// 1. estimate every segment's `(λ̂, θ̂)` from its trace history
///    (serial, deterministic — the RNG draws were already made by
///    [`segment_params`]);
/// 2. push one [`SelectBatch`] of every segment's interval search
///    through the facade: identical specs (common when segments share
///    history or fall back to the system rates) **dedupe into a single
///    model build**, unique specs fan out over the pool, and the engine
///    dispatch (native parallel / PJRT serial) lives in the facade;
/// 3. fan the simulations out over the pool, every segment walking one
///    **shared** [`ShardedIndex`] (window `opts.shard_window_days`) via
///    `Simulator::run_sharded`/`sweep_par_sharded`, so the merged
///    timeline is compiled once — in parallel — instead of once per
///    segment, and each walk touches only the shards its span overlaps.
///
/// Results are identical to the seed's serial loop (equivalence-pinned):
/// the facade's cold builders reproduce `select_interval` bit for bit,
/// duplicates share floats a re-run would reproduce anyway, and the
/// sharded walk is pinned field-for-field to the monolithic one.
///
/// Memory note: each concurrent search in phase 2 holds its own builder
/// caches, and every builder is dropped the moment its search completes
/// ([`SelectBatch::run_discarding_builders`] — only the `SearchResult`s
/// survive into phase 3), so peak memory scales with
/// `min(workers, unique specs)` — ~0.5 GB per concurrent build at
/// N = 512 (see `markov::builder`). Lower `opts.segments` or run the
/// serial [`run_segments_reference`] on memory-constrained machines.
pub fn run_segments(
    trace: &FailureTrace,
    app: &AppProfile,
    policy: &ReschedulingPolicy,
    engine: &ComputeEngine,
    sys: &SystemParams,
    opts: &ExperimentOptions,
    rng: &mut Rng,
) -> Result<AggregateEvaluation> {
    let params = segment_params(trace, opts, rng);
    let fallback = Some((sys.lambda, sys.theta));

    // Phase 1: per-segment rates.
    let rates: Vec<(f64, f64)> = params
        .iter()
        .map(|&(start, _)| segment_rates(trace, start, fallback))
        .collect::<Result<_>>()?;

    // Phase 2: one deduped interval-search batch through the facade.
    // Builders are discarded as each search completes — a sweep keeps
    // only the `SearchResult`s, so no builder outlives its build slot.
    let mut batch = SelectBatch::new();
    for &(lambda, theta) in &rates {
        let system = SystemParams::new(trace.n_procs(), lambda, theta);
        batch.push(SelectSpec::new(ModelInputs::new(system, app, policy)?, opts.search));
    }
    let searches: Vec<SearchResult> = batch
        .run_discarding_builders(engine)
        .into_iter()
        .map(|o| o.result.map(|ok| ok.search).map_err(anyhow::Error::from))
        .collect::<Result<_>>()?;

    // Phase 3: shared sharded index; simulations fan out (the simulator
    // is engine-independent, so even PJRT-searched segments parallelize).
    let sharded =
        ShardedIndex::new(trace, opts.shard_window_days * 86_400.0, pool::default_workers())?;
    let workers = pool::default_workers().min(params.len().max(1));
    let evals: Vec<Result<SegmentEvaluation>> = pool::run_indexed(params.len(), workers, |i| {
        let (start, dur) = params[i];
        let search = searches[i].clone();
        evaluate_segment_simulated(
            trace,
            app,
            policy,
            start,
            dur,
            &opts.search,
            rates[i],
            search,
            Some(&sharded),
        )
    });
    let mut agg = AggregateEvaluation::default();
    for eval in evals {
        agg.segments.push(eval?);
    }
    Ok(agg)
}

/// The seed's serial path over the same pre-drawn segments, evaluated
/// through [`evaluate_segment_reference`] — the end-to-end baseline for
/// `benches/perf.rs` and the equivalence suite. Consumes the RNG exactly
/// like [`run_segments`].
pub fn run_segments_reference(
    trace: &FailureTrace,
    app: &AppProfile,
    policy: &ReschedulingPolicy,
    engine: &ComputeEngine,
    sys: &SystemParams,
    opts: &ExperimentOptions,
    rng: &mut Rng,
) -> Result<AggregateEvaluation> {
    let params = segment_params(trace, opts, rng);
    let mut agg = AggregateEvaluation::default();
    for &(start, dur) in &params {
        let eval = evaluate_segment_reference(
            trace,
            app,
            policy,
            engine,
            start,
            dur,
            &opts.search,
            Some((sys.lambda, sys.theta)),
        )?;
        agg.segments.push(eval);
    }
    Ok(agg)
}

/// Fixed-width table printer for experiment output.
pub struct TablePrinter {
    widths: Vec<usize>,
}

impl TablePrinter {
    pub fn new(headers: &[&str], widths: &[usize]) -> TablePrinter {
        let t = TablePrinter { widths: widths.to_vec() };
        t.row(headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        t
    }

    pub fn row(&self, cells: &[&str]) {
        let mut line = String::new();
        for (cell, w) in cells.iter().zip(&self.widths) {
            line.push_str(&format!("{cell:>w$}  ", w = w));
        }
        println!("{}", line.trim_end());
    }
}
