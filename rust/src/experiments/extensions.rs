//! Paper §IX future-work extensions, implemented: sensitivity of the
//! model-selected interval to the *actual* failure distribution (the model
//! assumes exponential; real LANL/Condor data is closer to Weibull with
//! decreasing hazard).

use anyhow::Result;

use super::common::{ExperimentOptions, TablePrinter};
use crate::apps::AppProfile;
use crate::config::paper_system;
use crate::metrics::evaluate_segment;
use crate::policies::ReschedulingPolicy;
use crate::runtime::ComputeEngine;
use crate::traces::synth::{generate, SynthSpec};
use crate::simulator::{SimConfig, Simulator};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Run the Table-II-style evaluation with traces whose TTFs are Weibull
/// (shapes < 1 = bursty, 1 = exponential control, > 1 = wear-out) while the
/// model keeps its exponential assumption — quantifying the robustness the
/// paper leaves to future work.
pub fn weibull_sensitivity(engine: &ComputeEngine, opts: &ExperimentOptions) -> Result<Json> {
    println!("\n=== Extension (paper §IX): Weibull failure distributions ===");
    let sys = paper_system("condor/128").unwrap();
    let app = AppProfile::qr(sys.n);
    let policy = ReschedulingPolicy::greedy(sys.n);
    let shapes = [0.5, 0.7, 1.0, 1.5];
    let t = TablePrinter::new(&["Shape k", "Eff %", "I_model h"], &[8, 8, 10]);
    let mut rng = Rng::new(opts.seed ^ 0x3e1b);
    let mut rows = Vec::new();
    for &shape in &shapes {
        let spec = if (shape - 1.0f64).abs() < 1e-9 {
            SynthSpec::exponential(sys.n, sys.lambda, sys.theta, opts.trace_days * 86_400.0)
        } else {
            SynthSpec::weibull(sys.n, sys.lambda, sys.theta, shape, opts.trace_days * 86_400.0)
        };
        let trace = generate(&spec, &mut rng);
        let mut effs = Vec::new();
        let mut ivs = Vec::new();
        for _ in 0..opts.segments {
            let dur = rng.range(opts.dur_days.0, opts.dur_days.1) * 86_400.0;
            let latest = trace.horizon() - dur;
            let start = rng.range(0.2 * latest, latest);
            let eval = evaluate_segment(
                &trace, &app, &policy, engine, start, dur, &opts.search,
                Some((sys.lambda, sys.theta)),
            )?;
            effs.push(eval.efficiency);
            ivs.push(eval.i_model / 3_600.0);
        }
        let eff = effs.iter().sum::<f64>() / effs.len() as f64;
        let iv = ivs.iter().sum::<f64>() / ivs.len() as f64;
        t.row(&[&format!("{shape:.1}"), &format!("{eff:.2}"), &format!("{iv:.2}")]);
        let mut o = Json::obj();
        o.set("shape", Json::from(shape))
            .set("efficiency", Json::from(eff))
            .set("i_model_hours", Json::from(iv));
        rows.push(o);
    }
    let mut report = Json::obj();
    report.set("rows", Json::Arr(rows));
    Ok(report)
}

/// Paper §IX "heterogeneous systems" extension: per-node reliability
/// spread (lognormal MTTF multipliers) with an availability-aware
/// processor selection — the mechanism behind the paper's AB policy
/// advantage (Table IV) isolated and quantified.
pub fn heterogeneous(opts: &ExperimentOptions) -> Result<Json> {
    println!("\n=== Extension (paper §IX): heterogeneous node reliability ===");
    let sys = paper_system("condor/128").unwrap();
    let app = AppProfile::qr(sys.n);
    // Cap at half the pool: reliability-aware selection only has room to
    // choose when the policy uses fewer processors than are available.
    let cap = sys.n / 2;
    let policy =
        ReschedulingPolicy::from_vector((1..=sys.n).map(|t| t.min(cap)).collect())?.named("capped");
    let t = TablePrinter::new(
        &["sigma", "selection", "UW (x1e6)", "failures"],
        &[6, 12, 10, 9],
    );
    let mut rng = Rng::new(opts.seed ^ 0x4e7e);
    let mut rows = Vec::new();
    for sigma in [0.0, 0.8, 1.5] {
        let spec = crate::traces::synth::SynthSpec::heterogeneous(
            sys.n,
            sys.lambda,
            sys.theta,
            sigma,
            80.0 * 86_400.0,
        );
        let trace = crate::traces::synth::generate(&spec, &mut rng);
        for prefer in [false, true] {
            let mut cfg = SimConfig::new(10.0 * 86_400.0, 60.0 * 86_400.0, 1.53 * 3_600.0);
            cfg.prefer_reliable = prefer;
            let r = Simulator::new(&trace, &app, &policy).run(&cfg)?;
            let sel = if prefer { "reliable" } else { "first-fit" };
            t.row(&[
                &format!("{sigma:.1}"),
                sel,
                &format!("{:.2}", r.useful_work / 1e6),
                &r.failures.to_string(),
            ]);
            let mut o = Json::obj();
            o.set("sigma", Json::from(sigma))
                .set("selection", Json::from(sel))
                .set("uw", Json::from(r.useful_work))
                .set("failures", Json::from(r.failures));
            rows.push(o);
        }
    }
    println!("(reliability-aware selection pays off only when nodes differ — the");
    println!(" heterogeneity that drives the paper's AB-policy result)");
    let mut report = Json::obj();
    report.set("rows", Json::Arr(rows));
    Ok(report)
}
