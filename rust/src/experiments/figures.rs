//! Regeneration of the paper's Figures 4, 5 and 6 (plus the §VI-D
//! moldable-vs-malleable Condor contrast).

use anyhow::Result;

use super::common::{trace_for_system, ExperimentOptions, TablePrinter};
use crate::apps::{AppKind, AppProfile};
use crate::baselines::moldable::simulate_moldable;
use crate::config::{paper_system, SystemParams};
use crate::metrics::evaluate_segment;
use crate::policies::ReschedulingPolicy;
use crate::runtime::ComputeEngine;
use crate::simulator::{SimConfig, Simulator};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Figure 4: `workinunittime` (iterations/s) vs processor count for the
/// three applications, to 512 processors.
pub fn fig4() -> Json {
    println!("\n=== Figure 4: workinunittime vs processors ===");
    let procs: Vec<usize> = vec![1, 2, 4, 8, 16, 32, 64, 128, 256, 384, 512];
    let t = TablePrinter::new(&["Procs", "QR", "CG", "MD"], &[6, 8, 8, 8]);
    let apps: Vec<AppProfile> =
        AppKind::ALL.iter().map(|&k| AppProfile::paper_app(k, 512)).collect();
    let mut series = Json::obj();
    for (kind, app) in AppKind::ALL.iter().zip(&apps) {
        let ys: Vec<f64> = procs.iter().map(|&a| app.work_per_sec(a)).collect();
        series.set(kind.name(), Json::from(ys));
    }
    for &a in &procs {
        t.row(&[
            &a.to_string(),
            &format!("{:.3}", apps[0].work_per_sec(a)),
            &format!("{:.3}", apps[1].work_per_sec(a)),
            &format!("{:.3}", apps[2].work_per_sec(a)),
        ]);
    }
    let mut chart = crate::util::plot::Chart::new(
        "Figure 4: workinunittime vs processors",
        "processors",
        "iterations / second",
    );
    for (kind, app) in AppKind::ALL.iter().zip(&apps) {
        chart = chart.with_series(crate::util::plot::Series::line(
            kind.name(),
            procs.iter().map(|&a| (a as f64, app.work_per_sec(a))).collect(),
        ));
    }
    if let Err(e) = chart.save(std::path::Path::new("plots/fig4_workinunittime.svg")) {
        eprintln!("warning: could not write fig4 plot: {e}");
    } else {
        println!("(plot: plots/fig4_workinunittime.svg)");
    }

    let mut report = Json::obj();
    report
        .set("procs", Json::Arr(procs.iter().map(|&p| Json::from(p)).collect()))
        .set("series", series);
    report
}

/// Figure 5: one 80-day QR run on a 128-processor Condor pool with
/// `I = I_model`, C = R = 20 min worst-case overheads; prints the
/// processors-in-use timeline and the achieved UWT.
pub fn fig5(opts: &ExperimentOptions) -> Result<Json> {
    println!("\n=== Figure 5: 80-day QR execution on condor/128 ===");
    let sys = paper_system("condor/128").unwrap();
    let mut rng = Rng::new(opts.seed ^ 0xf165);
    let trace = trace_for_system(&sys, 100.0, &mut rng);
    let app = AppProfile::qr(sys.n);
    let policy = ReschedulingPolicy::greedy(sys.n);

    // The paper uses I_model = 1.53 h for this setting.
    let interval = 1.53 * 3_600.0;
    let mut cfg = SimConfig::new(5.0 * 86_400.0, 80.0 * 86_400.0, interval);
    cfg.ckpt_override = Some(20.0 * 60.0);
    cfg.rec_override = Some(20.0 * 60.0);
    cfg.record_timeline = true;

    let sim = Simulator::new(&trace, &app, &policy);
    let res = sim.run(&cfg)?;

    let max_rate = (1..=sys.n).map(|a| app.work_per_sec(a)).fold(0.0, f64::max);
    println!("UWT achieved: {:.2} ({:.0}% of failure-free max {max_rate:.2})", res.uwt, 100.0 * res.uwt / max_rate);
    println!("failures: {}, checkpoints: {}, waits: {:.1} h", res.failures, res.checkpoints, res.wait_seconds / 3600.0);

    // Coarse ASCII sparkline of processors in use (12 buckets).
    let t = TablePrinter::new(&["Day", "Procs in use"], &[6, 12]);
    let buckets = 12usize;
    for b in 0..buckets {
        let t0 = cfg.start + (b as f64 / buckets as f64) * cfg.duration;
        let a = res
            .timeline
            .iter()
            .rev()
            .find(|&&(ts, _)| ts <= t0)
            .map(|&(_, a)| a)
            .unwrap_or(0);
        t.row(&[&format!("{:.0}", (t0 - cfg.start) / 86_400.0), &a.to_string()]);
    }

    let chart = crate::util::plot::Chart::new(
        "Figure 5: QR on condor/128, 80 days (I = 1.53 h, C = R = 20 min)",
        "day",
        "processors in use",
    )
    .with_series(crate::util::plot::Series::step(
        "procs",
        res.timeline.iter().map(|&(ts, a)| ((ts - cfg.start) / 86_400.0, a as f64)).collect(),
    ));
    if let Err(e) = chart.save(std::path::Path::new("plots/fig5_condor_run.svg")) {
        eprintln!("warning: could not write fig5 plot: {e}");
    } else {
        println!("(plot: plots/fig5_condor_run.svg)");
    }

    let mut report = Json::obj();
    report
        .set("uwt", Json::from(res.uwt))
        .set("uwt_fraction_of_failure_free", Json::from(res.uwt / max_rate))
        .set("failures", Json::from(res.failures))
        .set("checkpoints", Json::from(res.checkpoints))
        .set(
            "timeline",
            Json::Arr(
                res.timeline
                    .iter()
                    .map(|&(ts, a)| Json::from(vec![(ts - cfg.start) / 86_400.0, a as f64]))
                    .collect(),
            ),
        );
    Ok(report)
}

/// Figure 6(a): model inefficiency vs failure rate (QR, condor-256 λ
/// scaled by the given factors, greedy).
pub fn fig6a(engine: &ComputeEngine, opts: &ExperimentOptions) -> Result<Json> {
    println!("\n=== Figure 6(a): inefficiency vs failure rate (QR, condor/256) ===");
    let base = paper_system("condor/256").unwrap();
    let factors = [0.25, 0.5, 1.0, 2.0, 4.0];
    let t = TablePrinter::new(&["λ scale", "MTTF d", "Ineff %"], &[8, 8, 8]);
    let mut rows = Vec::new();
    let mut rng = Rng::new(opts.seed ^ 0xf16a);
    for &f in &factors {
        let sys = SystemParams::new(base.n, base.lambda * f, base.theta);
        let trace = trace_for_system(&sys, opts.trace_days, &mut rng);
        let app = AppProfile::qr(sys.n);
        let policy = ReschedulingPolicy::greedy(sys.n);
        let mut pds = Vec::new();
        for _ in 0..opts.segments {
            let dur = rng.range(opts.dur_days.0, opts.dur_days.1) * 86_400.0;
            let start = rng.range(0.2, 0.6) * (trace.horizon() - dur);
            let eval = evaluate_segment(
                &trace, &app, &policy, engine, start, dur, &opts.search,
                Some((sys.lambda, sys.theta)),
            )?;
            pds.push(eval.pd);
        }
        let pd = pds.iter().sum::<f64>() / pds.len() as f64;
        t.row(&[
            &format!("{f:.2}x"),
            &format!("{:.1}", 1.0 / (sys.lambda * 86_400.0)),
            &format!("{pd:.2}"),
        ]);
        let mut o = Json::obj();
        o.set("lambda_scale", Json::from(f)).set("inefficiency", Json::from(pd));
        rows.push(o);
    }
    let mut report = Json::obj();
    report.set("rows", Json::Arr(rows));
    Ok(report)
}

/// Figure 6(b): model inefficiency vs execution duration (QR, condor/128).
pub fn fig6b(engine: &ComputeEngine, opts: &ExperimentOptions) -> Result<Json> {
    println!("\n=== Figure 6(b): inefficiency vs duration (QR, condor/128) ===");
    let sys = paper_system("condor/128").unwrap();
    let durations_days = [5.0, 10.0, 20.0, 40.0, 80.0];
    let t = TablePrinter::new(&["Days", "Ineff %"], &[6, 8]);
    let mut rng = Rng::new(opts.seed ^ 0xf16b);
    let trace = trace_for_system(&sys, 120.0, &mut rng);
    let app = AppProfile::qr(sys.n);
    let policy = ReschedulingPolicy::greedy(sys.n);
    let mut rows = Vec::new();
    for &days in &durations_days {
        let mut pds = Vec::new();
        for _ in 0..opts.segments {
            let dur = days * 86_400.0;
            let latest = trace.horizon() - dur;
            let start = rng.range(0.2 * latest, latest);
            let eval = evaluate_segment(
                &trace, &app, &policy, engine, start, dur, &opts.search,
                Some((sys.lambda, sys.theta)),
            )?;
            pds.push(eval.pd);
        }
        let pd = pds.iter().sum::<f64>() / pds.len() as f64;
        t.row(&[&format!("{days:.0}"), &format!("{pd:.2}")]);
        let mut o = Json::obj();
        o.set("days", Json::from(days)).set("inefficiency", Json::from(pd));
        rows.push(o);
    }
    let mut report = Json::obj();
    report.set("rows", Json::Arr(rows));
    Ok(report)
}

/// §VI-D contrast: moldable vs malleable, on (a) the published condor/128
/// rates and (b) a genuinely volatile interactive pool (machine
/// availability ≈ 70%, the regime Condor workstations actually live in),
/// where waiting for a fixed-size processor set strangles moldable runs.
pub fn moldable_vs_malleable(opts: &ExperimentOptions) -> Result<Json> {
    let mut report_rows = Vec::new();
    let engine = crate::runtime::ComputeEngine::native();
    let scenarios = [
        ("condor/128 published rates", paper_system("condor/128").unwrap()),
        (
            "volatile interactive pool (MTTF 8 h, MTTR 1.5 h)",
            SystemParams::new(128, 1.0 / (8.0 * 3_600.0), 1.0 / (1.5 * 3_600.0)),
        ),
    ];
    for (label, sys) in scenarios {
        println!("\n=== Moldable vs malleable: {label} (QR, 40 days) ===");
        println!("(every mode runs at its own model/Daly-selected interval — the paper's methodology)");
        let mut rng = Rng::new(opts.seed ^ 0x301d);
        let trace = trace_for_system(&sys, 60.0, &mut rng);
        let app = AppProfile::qr(sys.n);
        let (start, dur) = (5.0 * 86_400.0, 40.0 * 86_400.0);
        let t = TablePrinter::new(
            &["Mode", "Procs", "I used", "UW (x1e6)", "UWT", "Wait h"],
            &[16, 6, 10, 10, 8, 8],
        );
        let mut push = |mode: String, procs: String, interval: f64, uw: f64, uwt: f64, wait: f64| {
            t.row(&[
                &mode,
                &procs,
                &crate::util::stats::fmt_duration(interval),
                &format!("{:.2}", uw / 1e6),
                &format!("{uwt:.2}"),
                &format!("{:.1}", wait / 3_600.0),
            ]);
            let mut o = Json::obj();
            o.set("scenario", Json::from(label))
                .set("mode", Json::from(mode))
                .set("interval", Json::from(interval))
                .set("uw", Json::from(uw))
                .set("uwt", Json::from(uwt))
                .set("wait_seconds", Json::from(wait));
            report_rows.push(o);
        };

        // Malleable, greedy and AB policies, at the model-selected
        // interval — both selections pushed as one batch through the
        // facade (the policies differ, so the specs stay unique; the
        // batch still fans the two builds out in parallel).
        let policies = [
            ReschedulingPolicy::greedy(sys.n),
            ReschedulingPolicy::availability_based(&trace, 50, &mut rng)?,
        ];
        let mut batch = crate::api::SelectBatch::new();
        for policy in &policies {
            let inputs = crate::markov::ModelInputs::new(sys, &app, policy)?;
            batch.push(crate::api::SelectSpec::new(inputs, opts.search));
        }
        for (policy, outcome) in policies.iter().zip(batch.run(&engine)) {
            let sel = outcome.search()?;
            let mut cfg = SimConfig::new(start, dur, sel.interval);
            cfg.prefer_reliable = policy.name == "ab";
            let r = Simulator::new(&trace, &app, policy).run(&cfg)?;
            push(
                format!("malleable-{}", policy.name),
                format!("<={}", sys.n),
                sel.interval,
                r.useful_work,
                r.uwt,
                r.wait_seconds,
            );
        }

        // Moldable at fixed sizes, each at its Daly-optimal interval.
        for a in [1usize, 16, 64, 120] {
            let daly_i = crate::baselines::daly::daly_interval(
                app.checkpoint_cost(a),
                1.0 / (a as f64 * sys.lambda),
            )
            .max(60.0);
            let cfg = SimConfig::new(start, dur, daly_i);
            let m = simulate_moldable(&trace, &app, a, &cfg)?;
            push(
                format!("moldable-{a}"),
                a.to_string(),
                daly_i,
                m.useful_work,
                m.uwt,
                m.wait_seconds,
            );
        }
    }
    println!("\n(volatile pool: fixed large sizes stall or thrash; the malleable run with an");
    println!(" availability-aware policy keeps computing — the paper's §VI-D argument)");
    let mut report = Json::obj();
    report.set("rows", Json::Arr(report_rows));
    Ok(report)
}
