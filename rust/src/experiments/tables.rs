//! Regeneration of the paper's Tables I–IV.

use anyhow::Result;

use super::common::{run_segments, trace_for_system, ExperimentOptions, TablePrinter};
use crate::api::{select_one, SelectSpec};
use crate::apps::{AppKind, AppProfile};
use crate::config::{paper_system, SystemParams, TABLE2_SYSTEMS};
use crate::markov::ModelInputs;
use crate::policies::ReschedulingPolicy;
use crate::runtime::ComputeEngine;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Table I: checkpoint and recovery overheads (min/avg/max) per app.
/// Pure profile regeneration — the paper's numbers are benchmark inputs.
pub fn table1() -> Json {
    println!("\n=== Table I: checkpointing (C) and recovery (R) overheads (seconds) ===");
    let t = TablePrinter::new(
        &["App", "C min", "C avg", "C max", "R min", "R avg", "R max"],
        &[4, 8, 8, 8, 8, 8, 8],
    );
    let mut report = Json::obj();
    for kind in AppKind::ALL {
        let app = AppProfile::paper_app(kind, 512);
        let (cmin, cavg, cmax) = app.ckpt_stats();
        let (rmin, ravg, rmax) = app.rec_stats();
        t.row(&[
            kind.name(),
            &format!("{cmin:.2}"),
            &format!("{cavg:.2}"),
            &format!("{cmax:.2}"),
            &format!("{rmin:.2}"),
            &format!("{ravg:.2}"),
            &format!("{rmax:.2}"),
        ]);
        let mut o = Json::obj();
        o.set("c", Json::from(vec![cmin, cavg, cmax]))
            .set("r", Json::from(vec![rmin, ravg, rmax]));
        report.set(kind.name(), o);
    }
    report
}

/// One row of Table II/III/IV-style evaluations.
#[allow(clippy::too_many_arguments)]
fn eval_row(
    label: &str,
    sys: &SystemParams,
    app: &AppProfile,
    policy_kind: &str,
    engine: &ComputeEngine,
    opts: &ExperimentOptions,
    rng: &mut Rng,
    printer: &TablePrinter,
) -> Result<Json> {
    let trace = trace_for_system(sys, opts.trace_days, rng);
    let policy = match policy_kind {
        "greedy" => ReschedulingPolicy::greedy(sys.n),
        "pb" => ReschedulingPolicy::performance_based(app.work_vector())?,
        "ab" => ReschedulingPolicy::availability_based(&trace, 50, rng)?,
        other => anyhow::bail!("unknown policy {other}"),
    };
    let agg = run_segments(&trace, app, &policy, engine, sys, opts, rng)?;

    printer.row(&[
        label,
        &format!("{:.0}", sys.n as f64),
        &format!("1/({:.2} d)", 1.0 / (agg.mean_lambda() * 86_400.0)),
        &format!("1/({:.1} m)", 1.0 / (agg.mean_theta() * 60.0)),
        &format!("{:.2}", agg.mean_efficiency()),
        &format!("{:.2}", agg.mean_i_model_hours()),
        &format!("{:.2}", agg.mean_uwt_model()),
        &format!("{:.2}", agg.mean_uwt_sim()),
    ]);

    let mut o = Json::obj();
    o.set("label", Json::from(label))
        .set("n", Json::from(sys.n))
        .set("policy", Json::from(policy_kind))
        .set("efficiency", Json::from(agg.mean_efficiency()))
        .set("i_model_hours", Json::from(agg.mean_i_model_hours()))
        .set("uwt_model", Json::from(agg.mean_uwt_model()))
        .set("uwt_sim", Json::from(agg.mean_uwt_sim()))
        .set("uw_model", Json::from(agg.mean_uw_model()))
        .set("lambda", Json::from(agg.mean_lambda()))
        .set("theta", Json::from(agg.mean_theta()));
    Ok(o)
}

fn table_header() -> TablePrinter {
    TablePrinter::new(
        &["System", "Procs", "λ", "θ", "Eff %", "I_model h", "UWT(I_m)", "UWT(I_s)"],
        &[14, 6, 13, 12, 7, 10, 9, 9],
    )
}

/// Table II: QR + greedy across the seven published system rows.
pub fn table2(engine: &ComputeEngine, opts: &ExperimentOptions) -> Result<Json> {
    println!("\n=== Table II: model efficiencies across systems (QR, greedy) ===");
    let printer = table_header();
    let mut rng = Rng::new(opts.seed ^ 0x7ab1e2);
    let mut rows = Vec::new();
    for &(name, n, mttf, mttr) in TABLE2_SYSTEMS {
        let sys = SystemParams::from_mttf_mttr(n, mttf, mttr);
        let app = AppProfile::qr(n);
        rows.push(eval_row(name, &sys, &app, "greedy", engine, opts, &mut rng, &printer)?);
    }
    let mut report = Json::obj();
    report.set("rows", Json::Arr(rows));
    Ok(report)
}

/// Table III: the three applications on system-1/128, greedy.
pub fn table3(engine: &ComputeEngine, opts: &ExperimentOptions) -> Result<Json> {
    println!("\n=== Table III: model efficiencies per application (system-1/128, greedy) ===");
    let printer = table_header();
    let mut rng = Rng::new(opts.seed ^ 0x7ab1e3);
    let sys = paper_system("system-1/128").unwrap();
    let mut rows = Vec::new();
    for kind in AppKind::ALL {
        let app = AppProfile::paper_app(kind, sys.n);
        rows.push(eval_row(kind.name(), &sys, &app, "greedy", engine, opts, &mut rng, &printer)?);
    }
    let mut report = Json::obj();
    report.set("rows", Json::Arr(rows));
    Ok(report)
}

/// Table IV: the three rescheduling policies (QR, system-1/128).
pub fn table4(engine: &ComputeEngine, opts: &ExperimentOptions) -> Result<Json> {
    println!("\n=== Table IV: rescheduling policies (QR, system-1/128) ===");
    let printer = table_header();
    let mut rng = Rng::new(opts.seed ^ 0x7ab1e4);
    let sys = paper_system("system-1/128").unwrap();
    let app = AppProfile::qr(sys.n);
    let mut rows = Vec::new();
    for policy in ["greedy", "pb", "ab"] {
        rows.push(eval_row(policy, &sys, &app, policy, engine, opts, &mut rng, &printer)?);
    }
    let mut report = Json::obj();
    report.set("rows", Json::Arr(rows));
    Ok(report)
}

/// Model-only interval listing (diagnostic: UWT_I curve for one config).
pub fn interval_curve(
    sys: &SystemParams,
    app: &AppProfile,
    engine: &ComputeEngine,
    opts: &ExperimentOptions,
) -> Result<Json> {
    let policy = ReschedulingPolicy::greedy(sys.n);
    let inputs = ModelInputs::new(*sys, app, &policy)?;
    let res = select_one(SelectSpec::new(inputs, opts.search), engine)?.search;
    let mut report = Json::obj();
    report
        .set("i_model_hours", Json::from(res.interval / 3_600.0))
        .set("uwt", Json::from(res.uwt))
        .set(
            "probes",
            Json::Arr(
                res.probes
                    .iter()
                    .map(|&(i, u)| Json::from(vec![i / 3_600.0, u]))
                    .collect(),
            ),
        );
    Ok(report)
}
