//! Experiment harness regenerating every table and figure of the paper's
//! evaluation (§VI). Each experiment prints the paper's rows/series and
//! returns a machine-readable [`crate::util::Json`] report.

pub mod common;
pub mod extensions;
pub mod figures;
pub mod tables;

pub use common::ExperimentOptions;
