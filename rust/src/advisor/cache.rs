//! Sharded concurrent recommendation cache — the advisor's hot path.
//!
//! Builders and their selection results are keyed by a **canonical hash**
//! of everything that determines the recommendation:
//! `(SystemParams, application cost vectors, rescheduling policy vector,
//! search shape, build options)`. Canonical means semantic: two requests
//! that describe the same model — e.g. a `greedy` policy by name and the
//! identical `rp` vector spelled out — collapse to the same key, while
//! anything that changes the floats (worker count aside — results are
//! pinned worker-invariant by the PR 1 equivalence tier) changes it.
//!
//! Keys are distributed over independently locked **shards**, so
//! concurrent requests for different systems never contend on a lock;
//! repeat hits are an O(1) probe of one shard. Each shard evicts in LRU
//! order (a global atomic clock stamps every touch) once its slice of the
//! configurable memory budget is exceeded — an entry's cost is dominated
//! by its [`SharedBuilder`]'s interval-independent caches
//! ([`SharedBuilder::cache_bytes`]). One over-budget entry is allowed to
//! remain per shard: a single giant system must still cache, or every
//! request would rebuild it.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::markov::{ModelInputs, SharedBuilder};
use crate::search::{SearchConfig, SearchResult, SearchTrace};

/// Canonical cache key of one recommendation request — the same
/// definition [`crate::api::SelectBatch`] dedupes batches by
/// ([`crate::api::canonical_hash`], hoisted out of this module so the
/// cache keys and batch dedup can never drift apart; persisted
/// `SpecRecord`s carry these hashes, so the definition is
/// format-stable). `BuildOptions::workers` is deliberately excluded:
/// results are pinned worker-invariant.
pub fn canonical_key(inputs: &ModelInputs, cfg: &SearchConfig) -> u64 {
    crate::api::canonical_hash(inputs, cfg)
}

/// One cached recommendation: the shared builder (kept alive for warm
/// starts), the selection result, and the rates it was computed with.
#[derive(Clone)]
pub struct CacheEntry {
    pub key: u64,
    pub builder: Arc<SharedBuilder>,
    pub result: SearchResult,
    /// The search trajectory behind `result` — served by `/v1/explain`.
    /// Shared (`Arc`) so cloning entries out of the cache stays cheap.
    pub trace: Arc<SearchTrace>,
    /// Failure/repair rates the result was computed with (the drift
    /// reference for ingest-tracked systems).
    pub lambda: f64,
    pub theta: f64,
    /// Bytes charged against the memory budget.
    pub bytes: usize,
    /// Drift detected; a background re-selection is pending.
    pub stale: bool,
}

struct Shard {
    /// key -> (LRU stamp, entry).
    map: HashMap<u64, (u64, CacheEntry)>,
    bytes: usize,
}

/// Aggregate counters (monotone; read by `status`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub entries: usize,
    pub bytes: usize,
    pub budget_bytes: usize,
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
}

pub struct ShardedCache {
    shards: Vec<Mutex<Shard>>,
    /// Global LRU clock; every get/insert stamps with a fresh tick.
    clock: AtomicU64,
    shard_budget: usize,
    budget: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    insertions: AtomicU64,
    evictions: AtomicU64,
}

impl ShardedCache {
    pub fn new(n_shards: usize, budget_bytes: usize) -> ShardedCache {
        let n = n_shards.max(1);
        ShardedCache {
            shards: (0..n)
                .map(|_| Mutex::new(Shard { map: HashMap::new(), bytes: 0 }))
                .collect(),
            clock: AtomicU64::new(0),
            shard_budget: budget_bytes / n,
            budget: budget_bytes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            insertions: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: u64) -> &Mutex<Shard> {
        // Fibonacci remix of the FNV key so shard choice is independent of
        // the low bits a power-of-two map bucket would also use.
        let i = (key.wrapping_mul(0x9e37_79b9_7f4a_7c15) >> 32) as usize % self.shards.len();
        &self.shards[i]
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed)
    }

    /// O(1) lookup; a hit refreshes the entry's LRU stamp.
    pub fn get(&self, key: u64) -> Option<CacheEntry> {
        let mut shard = self.shard(key).lock().unwrap();
        match shard.map.get_mut(&key) {
            Some(slot) => {
                slot.0 = self.clock.fetch_add(1, Ordering::Relaxed);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(slot.1.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or replace) an entry, then evict least-recently-used
    /// entries while the shard exceeds its budget slice — always keeping
    /// at least one entry.
    pub fn insert(&self, entry: CacheEntry) {
        let stamp = self.tick();
        let key = entry.key;
        let added = entry.bytes;
        let mut shard = self.shard(key).lock().unwrap();
        if let Some((_, old)) = shard.map.insert(key, (stamp, entry)) {
            shard.bytes -= old.bytes;
        }
        shard.bytes += added;
        self.insertions.fetch_add(1, Ordering::Relaxed);
        while shard.bytes > self.shard_budget && shard.map.len() > 1 {
            let victim = shard.map.iter().min_by_key(|(_, v)| v.0).map(|(&k, _)| k).unwrap();
            let (_, gone) = shard.map.remove(&victim).unwrap();
            shard.bytes -= gone.bytes;
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Lookup without touching the hit/miss counters or the LRU stamp —
    /// `status` reporting must not perturb eviction order.
    pub fn peek(&self, key: u64) -> Option<CacheEntry> {
        let shard = self.shard(key).lock().unwrap();
        shard.map.get(&key).map(|(_, e)| e.clone())
    }

    /// Flag an entry as drift-stale (a background re-selection is on its
    /// way); returns a snapshot for seeding the re-selection.
    pub fn mark_stale(&self, key: u64) -> Option<CacheEntry> {
        let mut shard = self.shard(key).lock().unwrap();
        shard.map.get_mut(&key).map(|slot| {
            slot.1.stale = true;
            slot.1.clone()
        })
    }

    /// Drop an entry (the post-re-selection cleanup of the stale key).
    pub fn remove(&self, key: u64) -> bool {
        let mut shard = self.shard(key).lock().unwrap();
        match shard.map.remove(&key) {
            Some((_, gone)) => {
                shard.bytes -= gone.bytes;
                true
            }
            None => false,
        }
    }

    pub fn stats(&self) -> CacheStats {
        let mut entries = 0usize;
        let mut bytes = 0usize;
        for s in &self.shards {
            let s = s.lock().unwrap();
            entries += s.map.len();
            bytes += s.bytes;
        }
        CacheStats {
            entries,
            bytes,
            budget_bytes: self.budget,
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            insertions: self.insertions.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }

    /// All entries, ordered by key (deterministic `status` listings).
    pub fn snapshot(&self) -> Vec<CacheEntry> {
        let mut out: Vec<CacheEntry> = Vec::new();
        for s in &self.shards {
            let s = s.lock().unwrap();
            out.extend(s.map.values().map(|(_, e)| e.clone()));
        }
        out.sort_by_key(|e| e.key);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemParams;
    use crate::markov::BuildOptions;
    use crate::policies::ReschedulingPolicy;

    fn inputs(n: usize, mttf_days: f64) -> ModelInputs {
        let system = SystemParams::from_mttf_mttr(n, mttf_days, 45.0);
        ModelInputs::from_raw(
            system,
            vec![60.0; n],
            (1..=n).map(|a| (a as f64).powf(0.85)).collect(),
            vec![15.0; n],
            ReschedulingPolicy::greedy(n),
        )
        .unwrap()
    }

    fn entry(key: u64, bytes: usize) -> CacheEntry {
        let inp = inputs(4, 2.0);
        CacheEntry {
            key,
            builder: Arc::new(SharedBuilder::native(inp.clone(), &BuildOptions::default())),
            result: SearchResult {
                interval: 3_600.0,
                uwt: 1.0,
                best_probed: 3_600.0,
                probes: vec![(3_600.0, 1.0)],
                evaluations: 1,
            },
            trace: Arc::new(SearchTrace::default()),
            lambda: inp.system.lambda,
            theta: inp.system.theta,
            bytes,
            stale: false,
        }
    }

    #[test]
    fn canonical_key_is_semantic() {
        let cfg = SearchConfig::default();
        let a = canonical_key(&inputs(6, 2.0), &cfg);
        let b = canonical_key(&inputs(6, 2.0), &cfg);
        assert_eq!(a, b, "identical specs must collide");
        // Rates, sizes, costs, policy and search shape all re-key.
        assert_ne!(a, canonical_key(&inputs(6, 3.0), &cfg));
        assert_ne!(a, canonical_key(&inputs(7, 2.0), &cfg));
        let base = inputs(6, 2.0);
        let dear = ModelInputs::from_raw(
            base.system,
            vec![90.0; 6],
            (1..=6).map(|x| (x as f64).powf(0.85)).collect(),
            vec![15.0; 6],
            ReschedulingPolicy::greedy(6),
        )
        .unwrap();
        assert_ne!(a, canonical_key(&dear, &cfg));
        let wider = SearchConfig { band: 0.2, ..cfg };
        assert_ne!(a, canonical_key(&inputs(6, 2.0), &wider));
        let exact = SearchConfig {
            build: BuildOptions { exact_probes: true, ..Default::default() },
            ..cfg
        };
        assert_ne!(a, canonical_key(&inputs(6, 2.0), &exact));
        // Worker count is *not* semantic (results are worker-invariant).
        let threads = SearchConfig {
            build: BuildOptions { workers: 31, ..Default::default() },
            ..cfg
        };
        assert_eq!(a, canonical_key(&inputs(6, 2.0), &threads));
    }

    #[test]
    fn canonical_key_policy_by_vector_not_name() {
        let cfg = SearchConfig::default();
        let named = inputs(5, 2.0); // greedy by constructor
        let spelled = ModelInputs::from_raw(
            named.system,
            vec![60.0; 5],
            (1..=5).map(|a| (a as f64).powf(0.85)).collect(),
            vec![15.0; 5],
            ReschedulingPolicy::from_vector((1..=5).collect()).unwrap(),
        )
        .unwrap();
        assert_eq!(canonical_key(&named, &cfg), canonical_key(&spelled, &cfg));
        let capped = ModelInputs::from_raw(
            named.system,
            vec![60.0; 5],
            (1..=5).map(|a| (a as f64).powf(0.85)).collect(),
            vec![15.0; 5],
            ReschedulingPolicy::from_vector((1..=5).map(|t| t.min(3)).collect()).unwrap(),
        )
        .unwrap();
        assert_ne!(canonical_key(&named, &cfg), canonical_key(&capped, &cfg));
    }

    #[test]
    fn hit_refreshes_and_miss_counts() {
        let cache = ShardedCache::new(4, 1 << 20);
        assert!(cache.get(42).is_none());
        cache.insert(entry(42, 100));
        let got = cache.get(42).expect("inserted entry must hit");
        assert_eq!(got.key, 42);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
    }

    #[test]
    fn lru_evicts_oldest_under_budget() {
        // Single shard, budget fits two 100-byte entries.
        let cache = ShardedCache::new(1, 250);
        cache.insert(entry(1, 100));
        cache.insert(entry(2, 100));
        assert_eq!(cache.stats().entries, 2);
        // Touch 1 so 2 becomes the LRU victim.
        assert!(cache.get(1).is_some());
        cache.insert(entry(3, 100));
        assert!(cache.get(1).is_some(), "recently used entry evicted");
        assert!(cache.get(3).is_some(), "fresh entry evicted");
        assert!(cache.get(2).is_none(), "LRU entry survived over budget");
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        assert!(s.bytes <= 250);
    }

    #[test]
    fn oversized_entry_still_cached() {
        let cache = ShardedCache::new(1, 50);
        cache.insert(entry(7, 500));
        assert!(cache.get(7).is_some(), "a lone over-budget entry must remain");
        cache.insert(entry(8, 500));
        assert_eq!(cache.stats().entries, 1, "second over-budget entry must evict down to one");
    }

    #[test]
    fn mark_stale_and_remove() {
        let cache = ShardedCache::new(2, 1 << 20);
        cache.insert(entry(5, 10));
        let snap = cache.mark_stale(5).expect("entry exists");
        assert!(snap.stale);
        assert!(cache.get(5).unwrap().stale);
        assert!(cache.remove(5));
        assert!(!cache.remove(5));
        assert!(cache.get(5).is_none());
        assert!(cache.mark_stale(99).is_none());
    }

    #[test]
    fn replacing_entry_updates_bytes() {
        let cache = ShardedCache::new(1, 1 << 20);
        cache.insert(entry(9, 100));
        cache.insert(entry(9, 40));
        let s = cache.stats();
        assert_eq!(s.entries, 1);
        assert_eq!(s.bytes, 40);
    }
}
