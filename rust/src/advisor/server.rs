//! The advisor's HTTP/1.1 front end: a `std::net::TcpListener` accept
//! loop feeding a fixed pool of handler threads through a condvar'd
//! queue, plus one background thread draining the re-selection queue and
//! compacting oversized track WALs. Hand-rolled like the rest of the
//! substrate (`util::cli`, `util::json`) — the vendor set has no
//! hyper/tokio, and the protocol surface is a handful of endpoints of
//! `Content-Length`-framed JSON.
//!
//! | endpoint           | method | body                                  |
//! |--------------------|--------|---------------------------------------|
//! | `/healthz`         | GET    | —                                     |
//! | `/v1/select`       | POST   | [`protocol::parse_select`]            |
//! | `/v1/select_batch` | POST   | [`protocol::parse_select_batch`]      |
//! | `/v1/model`        | POST   | [`protocol::parse_model`]             |
//! | `/v1/ingest`       | POST   | [`protocol::parse_ingest`]            |
//! | `/v1/status`       | GET    | —                                     |
//! | `/v1/shutdown`     | POST   | — (stops the daemon; used by tests    |
//! |                    |        | and the CI smoke job)                 |
//! | `/v1/replicate/manifest` | GET | — (segment manifest; `--data-dir`) |
//! | `/v1/replicate/segment`  | GET | `?track=&name=&offset=` range fetch |
//! | `/v1/explain`      | GET    | `?key=<16 hex>` or `?track=<id>` — the  |
//! |                    |        | search trajectory behind a cached       |
//! |                    |        | recommendation (DESIGN.md §15)          |
//! | `/v1/debug/trace`  | GET    | `?request_id=<id>` filter — recent      |
//! |                    |        | request span trees from the trace ring  |
//!
//! With `serve --auth-token T`, every `/v1/*` route requires
//! `Authorization: Bearer T` (`401` JSON otherwise); `/healthz` stays
//! open so load balancers can probe without credentials. With
//! `serve --replica-of URL` the daemon is a read replica: a background
//! puller mirrors the primary's store ([`super::replicate`]) and
//! `POST /v1/ingest` answers `409` pointing writers at the primary.
//!
//! Malformed requests get `400` with `{"ok": false, "error": ...}`;
//! unknown paths `404`; wrong methods `405`; a POST without a
//! `Content-Length` `411` (the daemon never reads until EOF); oversized
//! frames `413`; a request that dribbles in past the read deadline `408`.
//! Model-layer failures surface as `500` — by the time a request reaches
//! the model layer its fields are validated, so a 500 is a bug, not bad
//! input.
//!
//! ## Overload and shedding
//!
//! Accepted connections wait in a **bounded** FIFO for one of the fixed
//! worker threads. When the queue is full — or the daemon is draining —
//! newcomers are shed immediately with `503` + `Retry-After: 1` instead
//! of piling up: under saturation the daemon degrades to fast rejections,
//! never to unbounded memory or hung clients. Per-connection socket
//! timeouts plus a whole-request read deadline ([`REQUEST_DEADLINE`])
//! bound how long a slow-loris client can hold a worker.
//!
//! ## Graceful drain
//!
//! `POST /v1/shutdown` flips the stop flag: the accept loop stops
//! queueing (shedding new connections with `503`), workers finish every
//! queued and in-flight request (keep-alive connections close after their
//! current response), and only when the last connection completes does
//! `run` return — snapshotting all persisted tracks on the way out.
//!
//! ## Keep-alive
//!
//! Connections are persistent per HTTP/1.1 defaults: a worker keeps
//! serving requests on one socket until the client sends
//! `Connection: close` (or speaks HTTP/1.0 without `keep-alive`), the
//! idle timeout lapses, the daemon begins shutting down, or
//! `MAX_REQUESTS_PER_CONN` requests have been answered — the bound
//! stops one chatty client from pinning a worker forever. Pipelined
//! bytes beyond one request stay buffered for the next read, so an
//! ingest stream pays one TCP handshake for a whole session instead of
//! one per event batch.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::{protocol, replicate, Advisor, AdvisorConfig};
use crate::obs::{self, log as olog, trace};
use crate::store::TraceStore;
use crate::util::json::Json;

/// Cap on header block and body sizes — the daemon fails fast on garbage
/// rather than buffering it.
const MAX_HEAD_BYTES: usize = 64 * 1024;
const MAX_BODY_BYTES: usize = 8 * 1024 * 1024;

/// Requests served on one connection before it is closed regardless of
/// keep-alive (fairness bound; clients reconnect transparently).
const MAX_REQUESTS_PER_CONN: usize = 256;

/// Per-connection socket timeout: a stalled client must not pin a worker.
/// Doubles as the keep-alive idle timeout between requests.
const IO_TIMEOUT: Duration = Duration::from_secs(10);

/// Whole-request read deadline, counted from the first byte of a request
/// to its last: a slow-loris client dribbling one byte per socket-timeout
/// window still loses its worker after this long (`408`).
pub(crate) const REQUEST_DEADLINE: Duration = Duration::from_secs(10);

/// `serve` front-end options.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Bind address, e.g. `127.0.0.1:7743` (port 0 = ephemeral).
    pub addr: String,
    /// Handler threads.
    pub workers: usize,
    /// Accepted connections allowed to wait for a worker; past this the
    /// daemon sheds newcomers with `503` + `Retry-After` instead of
    /// queueing without bound.
    pub queue_depth: usize,
    pub advisor: AdvisorConfig,
    /// Require `Authorization: Bearer <token>` on every `/v1/*` route
    /// (`/healthz` stays open for unauthenticated health probes).
    pub auth_token: Option<String>,
    /// Run as a read replica of this primary (`host:port` or
    /// `http://host:port`): a background puller mirrors the primary's
    /// store into `--data-dir` and ingest is rejected with `409`.
    pub replica_of: Option<String>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:7743".to_string(),
            workers: crate::util::pool::default_workers().clamp(2, 8),
            queue_depth: 128,
            advisor: AdvisorConfig::default(),
            auth_token: None,
            replica_of: None,
        }
    }
}

/// A parsed request frame.
pub(crate) struct HttpRequest {
    pub(crate) method: String,
    pub(crate) path: String,
    pub(crate) body: String,
    /// Client wants the connection kept open after the response
    /// (HTTP/1.1 default; overridden by a `Connection` header).
    pub(crate) keep_alive: bool,
    /// Raw `Authorization` header value, if the client sent one.
    pub(crate) authorization: Option<String>,
    /// Monotonic per-process request id ([`obs::next_request_id`]),
    /// assigned when the handler picks the frame up (0 = unassigned, e.g.
    /// inside the parser-level fuzz target). Echoed as `X-Request-Id` and
    /// carried through routing so one slow select can be traced from
    /// accept to response in the structured logs.
    pub(crate) id: u64,
}

/// Per-daemon routing configuration threaded into [`route`]: the auth
/// token requests must carry and, for a read replica, the primary
/// address writes are redirected to.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct RouteContext<'a> {
    pub(crate) auth_token: Option<&'a str>,
    pub(crate) replica_of: Option<&'a str>,
}

/// What one read attempt on a (possibly reused) connection produced.
enum ReadOutcome {
    Request(HttpRequest),
    /// The client hung up (or idled past the timeout) between requests —
    /// a normal keep-alive end, nothing to answer.
    Closed,
    /// Bytes arrived but do not form a valid request — answer with the
    /// carried status code (`400`/`408`/`411`/`413`) and close.
    Malformed(u16, String),
}

/// Try to parse one complete request frame from `buf` without touching a
/// socket — the byte-level core of [`read_request`] and the entry point
/// the fuzz harness's `http` target hammers. Returns `Ok(Some((request,
/// consumed_bytes)))` for a complete frame, `Ok(None)` when more bytes
/// are needed, `Err((status, reason))` when the bytes can never become a
/// valid request. Never panics, never allocates beyond the framing caps.
pub(crate) fn try_parse_request(
    buf: &[u8],
) -> std::result::Result<Option<(HttpRequest, usize)>, (u16, String)> {
    let Some(head_end) = find_head_end(buf) else {
        if buf.len() > MAX_HEAD_BYTES {
            return Err((400, format!("header block exceeds {MAX_HEAD_BYTES} bytes")));
        }
        return Ok(None);
    };
    // srclint: allow(no-panic-paths) — find_head_end returns a window position, so head_end <= buf.len()
    let head = match std::str::from_utf8(&buf[..head_end]) {
        Ok(h) => h,
        Err(_) => return Err((400, "non-UTF-8 request head".to_string())),
    };
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("HTTP/1.1");
    if method.is_empty() || path.is_empty() {
        return Err((400, format!("malformed request line '{request_line}'")));
    }
    // HTTP/1.1 defaults to persistent connections; 1.0 to closing.
    let mut keep_alive = version != "HTTP/1.0";
    let mut content_length: Option<usize> = None;
    let mut authorization: Option<String> = None;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            let value = value.trim();
            if name.eq_ignore_ascii_case("content-length") {
                content_length = match value.parse::<usize>() {
                    Ok(n) => Some(n),
                    Err(_) => return Err((400, format!("bad Content-Length '{value}'"))),
                };
            } else if name.eq_ignore_ascii_case("transfer-encoding") {
                // Never read-until-EOF or dechunk: bodies must be framed
                // by an explicit Content-Length.
                return Err((411, "Transfer-Encoding unsupported; send Content-Length".to_string()));
            } else if name.eq_ignore_ascii_case("connection") {
                if value.eq_ignore_ascii_case("close") {
                    keep_alive = false;
                } else if value.eq_ignore_ascii_case("keep-alive") {
                    keep_alive = true;
                }
            } else if name.eq_ignore_ascii_case("authorization") {
                authorization = Some(value.to_string());
            }
        }
    }
    let content_length = match content_length {
        Some(n) => n,
        // Bodyless methods default to an empty body; a POST/PUT without a
        // length would mean reading until EOF — refuse instead.
        None if matches!(method.as_str(), "POST" | "PUT" | "PATCH") => {
            return Err((411, format!("{method} requires a Content-Length")));
        }
        None => 0,
    };
    if content_length > MAX_BODY_BYTES {
        return Err((413, format!("body of {content_length} bytes exceeds {MAX_BODY_BYTES}")));
    }
    let frame_end = head_end + 4 + content_length;
    if buf.len() < frame_end {
        return Ok(None);
    }
    // srclint: allow(no-panic-paths) — frame_end <= buf.len() checked above, and head_end + 4 <= frame_end
    let body = match std::str::from_utf8(&buf[head_end + 4..frame_end]) {
        Ok(b) => b.to_string(),
        Err(_) => return Err((400, "non-UTF-8 request body".to_string())),
    };
    Ok(Some((HttpRequest { method, path, body, keep_alive, authorization, id: 0 }, frame_end)))
}

/// Read one request from `stream`, carrying leftover bytes across calls
/// in `buf` (pipelined requests on a keep-alive connection must not be
/// dropped with the frame that preceded them). The [`REQUEST_DEADLINE`]
/// clock starts at the request's first byte, so keep-alive idle time does
/// not count against it.
fn read_request(stream: &mut TcpStream, buf: &mut Vec<u8>) -> ReadOutcome {
    let mut chunk = [0u8; 4096];
    let mut deadline: Option<Instant> =
        (!buf.is_empty()).then(|| Instant::now() + REQUEST_DEADLINE);
    loop {
        match try_parse_request(buf) {
            Ok(Some((req, consumed))) => {
                // Keep pipelined bytes beyond this frame for the next read.
                buf.drain(..consumed);
                return ReadOutcome::Request(req);
            }
            Ok(None) => {}
            Err((code, msg)) => return ReadOutcome::Malformed(code, msg),
        }
        if deadline.is_some_and(|d| Instant::now() >= d) {
            return ReadOutcome::Malformed(408, "request read deadline exceeded".to_string());
        }
        match stream.read(&mut chunk) {
            Ok(0) if buf.is_empty() => return ReadOutcome::Closed,
            Ok(0) => return ReadOutcome::Malformed(400, "connection closed mid-request".to_string()),
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                deadline.get_or_insert_with(|| Instant::now() + REQUEST_DEADLINE);
            }
            Err(_) if buf.is_empty() => return ReadOutcome::Closed, // idle timeout
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                return ReadOutcome::Malformed(408, format!("timed out mid-request: {e}"));
            }
            Err(e) => return ReadOutcome::Malformed(400, format!("reading request: {e}")),
        }
    }
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Every route the daemon serves, in the label form the metric families
/// use. Unknown paths fall into the `other` series so a path scan cannot
/// grow the exposition (DESIGN.md §14 cardinality rules).
const ROUTES: &[&str] = &[
    "/healthz",
    "/metrics",
    "/v1/status",
    "/v1/select",
    "/v1/select_batch",
    "/v1/model",
    "/v1/ingest",
    "/v1/shutdown",
    "/v1/replicate/manifest",
    "/v1/replicate/segment",
    "/v1/explain",
    "/v1/debug/trace",
];

/// Every status code routing can produce (`status_lines_cover_every_
/// emitted_code` pins `status_text` over the same list).
const EMITTED_CODES: &[u16] = &[200, 400, 401, 404, 405, 408, 409, 411, 413, 500, 503];

/// Resolved-once handles for the server's metric families; every request
/// after the first costs only relaxed atomic ops.
pub(crate) struct HttpObs {
    requests: Vec<Arc<obs::Counter>>,
    latency: Vec<Arc<obs::Histogram>>,
    other_requests: Arc<obs::Counter>,
    other_latency: Arc<obs::Histogram>,
    responses: Vec<Arc<obs::Counter>>,
    other_responses: Arc<obs::Counter>,
    in_flight: Arc<obs::Gauge>,
    queue_depth: Arc<obs::Gauge>,
    shed_total: Arc<obs::Counter>,
}

impl HttpObs {
    fn new() -> HttpObs {
        let reg = obs::global();
        const REQ_HELP: &str = "HTTP requests accepted, by route.";
        const LAT_HELP: &str = "Request latency from parse to response flush, by route.";
        const RESP_HELP: &str = "HTTP responses written, by status code.";
        let requests = ROUTES
            .iter()
            .map(|r| reg.counter_with("mckpt_http_requests_total", REQ_HELP, &[("route", r)]))
            .collect();
        let latency = ROUTES
            .iter()
            .map(|r| {
                reg.histogram_with(
                    "mckpt_http_request_seconds",
                    LAT_HELP,
                    obs::LATENCY_BUCKETS,
                    &[("route", r)],
                )
            })
            .collect();
        let responses = EMITTED_CODES
            .iter()
            .map(|c| {
                let code = c.to_string();
                reg.counter_with("mckpt_http_responses_total", RESP_HELP, &[("code", &code)])
            })
            .collect();
        HttpObs {
            requests,
            latency,
            other_requests: reg.counter_with(
                "mckpt_http_requests_total",
                REQ_HELP,
                &[("route", "other")],
            ),
            other_latency: reg.histogram_with(
                "mckpt_http_request_seconds",
                LAT_HELP,
                obs::LATENCY_BUCKETS,
                &[("route", "other")],
            ),
            responses,
            other_responses: reg.counter_with(
                "mckpt_http_responses_total",
                RESP_HELP,
                &[("code", "other")],
            ),
            in_flight: reg.gauge("mckpt_http_in_flight", "Requests currently being handled."),
            queue_depth: reg
                .gauge("mckpt_http_queue_depth", "Accepted connections waiting for a worker."),
            shed_total: reg.counter(
                "mckpt_http_shed_total",
                "Connections shed with 503 (queue full or draining).",
            ),
        }
    }

    /// Request counter + latency histogram for a path (query stripped by
    /// the caller); unknown paths share the `other` series.
    fn route_handles(&self, path: &str) -> (&obs::Counter, &obs::Histogram) {
        match ROUTES.iter().position(|r| *r == path) {
            Some(i) => (&self.requests[i], &self.latency[i]),
            None => (&self.other_requests, &self.other_latency),
        }
    }

    fn response(&self, code: u16) {
        match EMITTED_CODES.iter().position(|c| *c == code) {
            Some(i) => self.responses[i].inc(),
            None => self.other_responses.inc(),
        }
    }
}

/// The server's metric handles (also the family pre-registration hook
/// `Advisor::publish_obs` touches so a first scrape lists every family).
pub(crate) fn http_obs() -> &'static HttpObs {
    static OBS: OnceLock<HttpObs> = OnceLock::new();
    OBS.get_or_init(HttpObs::new)
}

/// Reason phrase for every code routing emits ([`EMITTED_CODES`]). The
/// fallback is deliberately *not* a real reason phrase: an unknown code
/// reaching the wire means a dispatch arm forgot to register here, and
/// `status_lines_cover_every_emitted_code` pins that it never happens.
fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        401 => "Unauthorized",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        411 => "Length Required",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown Status",
    }
}

/// Write one response frame. `req_id` (when the request got far enough to
/// be assigned one) is echoed as `X-Request-Id` so a client-observed
/// latency can be matched to the daemon's structured logs.
fn write_response_raw(
    stream: &mut TcpStream,
    code: u16,
    content_type: &str,
    payload: &[u8],
    keep_alive: bool,
    req_id: Option<u64>,
) {
    // The 503 shedding contract: tell well-behaved clients when to come
    // back instead of letting them hammer a saturated daemon.
    let retry_after = if code == 503 { "Retry-After: 1\r\n" } else { "" };
    let req_id_hdr = match req_id {
        Some(id) => format!("X-Request-Id: {id}\r\n"),
        None => String::new(),
    };
    let head = format!(
        "HTTP/1.1 {code} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n{retry_after}{req_id_hdr}Connection: {}\r\n\r\n",
        status_text(code),
        payload.len(),
        if keep_alive { "keep-alive" } else { "close" }
    );
    // Best effort: the client may already be gone.
    let _ = stream.write_all(head.as_bytes());
    let _ = stream.write_all(payload);
    let _ = stream.flush();
    http_obs().response(code);
}

fn write_response(
    stream: &mut TcpStream,
    code: u16,
    body: &Json,
    keep_alive: bool,
    req_id: Option<u64>,
) {
    let payload = body.to_compact();
    write_response_raw(stream, code, "application/json", payload.as_bytes(), keep_alive, req_id);
}

/// Best-effort `503 Retry-After` on a connection the daemon will not
/// serve (queue full or draining), then drop it. A short write timeout
/// keeps shedding itself from blocking the accept loop.
fn shed(mut stream: TcpStream, why: &str) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_write_timeout(Some(Duration::from_millis(500)));
    let _ = stream.set_nodelay(true);
    http_obs().shed_total.inc();
    write_response(&mut stream, 503, &protocol::error_response(why), false, None);
}

/// First `name=value` query parameter called `name`, raw (no percent
/// decoding: segment and track names are already in their wire form).
fn query_param<'a>(query: &'a str, name: &str) -> Option<&'a str> {
    query
        .split('&')
        .filter_map(|pair| pair.split_once('='))
        .find(|(k, _)| *k == name)
        .map(|(_, v)| v)
}

/// Route one request. Parse errors are 400s; model-layer errors 500s.
fn route(advisor: &Advisor, req: &HttpRequest, stop: &AtomicBool, ctx: RouteContext) -> (u16, Json) {
    let parse_body = || -> Result<Json> { Ok(Json::parse(&req.body)?) };
    let (path, query) = req.path.split_once('?').unwrap_or((req.path.as_str(), ""));
    // The auth gate runs before any dispatch: with a configured token,
    // every route except the load-balancer health probe requires
    // `Authorization: Bearer <token>` verbatim.
    if let Some(token) = ctx.auth_token {
        if path != "/healthz" {
            let _auth = trace::span("auth");
            let want = format!("Bearer {token}");
            if req.authorization.as_deref() != Some(want.as_str()) {
                return (401, protocol::error_response("missing or invalid bearer token"));
            }
        }
    }
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => {
            let mut o = Json::obj();
            o.set("ok", Json::from(true));
            (200, o)
        }
        ("GET", "/v1/status") => (200, advisor.status()),
        ("GET", "/v1/explain") => {
            // Addressed by cache key (the 16-hex `key` every select
            // response carries) or by tracked id; peeks only, so probing
            // explain never perturbs LRU order.
            match (query_param(query, "key"), query_param(query, "track")) {
                (Some(hex), _) => match u64::from_str_radix(hex, 16) {
                    Ok(k) => match advisor.explain_key(k) {
                        Some(j) => (200, j),
                        None => (
                            404,
                            protocol::error_response("no cached entry for that key"),
                        ),
                    },
                    Err(_) => {
                        (400, protocol::error_response("bad 'key' (expected 16 hex digits)"))
                    }
                },
                (None, Some(t)) => match advisor.explain_track(t) {
                    Some(j) => (200, j),
                    None => (404, protocol::error_response("no such track")),
                },
                (None, None) => (
                    400,
                    protocol::error_response("'key' or 'track' query parameter required"),
                ),
            }
        }
        ("GET", "/v1/debug/trace") => match query_param(query, "request_id") {
            Some(raw) => match raw.parse::<u64>() {
                Ok(id) => (200, trace::ring().export(Some(id))),
                Err(_) => (400, protocol::error_response("bad 'request_id' query parameter")),
            },
            None => (200, trace::ring().export(None)),
        },
        ("GET", "/v1/replicate/manifest") => match advisor.store() {
            Some(st) => match replicate::manifest_json(st) {
                Ok(j) => (200, j),
                Err(e) => (500, protocol::error_response(&format!("{e:#}"))),
            },
            None => (400, protocol::error_response("replication requires serve --data-dir")),
        },
        ("GET", "/v1/replicate/segment") => match advisor.store() {
            Some(st) => {
                let track = query_param(query, "track").unwrap_or("");
                let name = query_param(query, "name").unwrap_or("");
                let offset = match query_param(query, "offset").unwrap_or("0").parse::<u64>() {
                    Ok(n) => n,
                    Err(_) => {
                        return (400, protocol::error_response("bad 'offset' query parameter"))
                    }
                };
                if track.is_empty() || name.is_empty() {
                    return (
                        400,
                        protocol::error_response("'track' and 'name' query parameters required"),
                    );
                }
                match replicate::segment_json(st, track, name, offset) {
                    Ok(j) => (200, j),
                    // Segment errors are client mistakes (bad names, raced
                    // compaction unlinks), not daemon bugs.
                    Err(e) => (400, protocol::error_response(&format!("{e:#}"))),
                }
            }
            None => (400, protocol::error_response("replication requires serve --data-dir")),
        },
        ("POST", "/v1/select") => match parse_body().and_then(|j| protocol::parse_select(&j)) {
            Ok(r) => {
                let timer = obs::timer();
                match advisor.select(&r) {
                    Ok(j) => {
                        // The request id links this model-layer timing to
                        // the access-log line for the same request.
                        if olog::enabled(olog::Level::Debug) {
                            let mut fields = vec![
                                ("req", Json::from(req.id)),
                                ("cached", j.get("cached").cloned().unwrap_or(Json::Null)),
                            ];
                            if let Some(s) = timer.elapsed_s() {
                                fields.push(("ms", Json::from(s * 1e3)));
                            }
                            olog::debug("server", "select", &fields);
                        }
                        (200, j)
                    }
                    Err(e) => (500, protocol::error_response(&format!("{e:#}"))),
                }
            }
            Err(e) => (400, protocol::error_response(&format!("{e:#}"))),
        },
        ("POST", "/v1/select_batch") => {
            match parse_body().and_then(|j| protocol::parse_select_batch(&j)) {
                // Runtime failures are per-item objects inside the 200
                // envelope; only a malformed body (failing index named)
                // is a 400.
                Ok(reqs) => (200, advisor.select_batch(&reqs)),
                Err(e) => (400, protocol::error_response(&format!("{e:#}"))),
            }
        }
        ("POST", "/v1/model") => match parse_body().and_then(|j| protocol::parse_model(&j)) {
            Ok(r) => match advisor.model(&r) {
                Ok(j) => (200, j),
                Err(e) => (500, protocol::error_response(&format!("{e:#}"))),
            },
            Err(e) => (400, protocol::error_response(&format!("{e:#}"))),
        },
        ("POST", "/v1/ingest") => {
            // A read replica owns no track history of its own — writes
            // must go to the primary the puller mirrors.
            if let Some(primary) = ctx.replica_of {
                let mut o =
                    protocol::error_response("read replica: ingest on the primary instead");
                o.set("primary", Json::from(primary));
                return (409, o);
            }
            match parse_body().and_then(|j| protocol::parse_ingest(&j)) {
                Ok(r) => match advisor.ingest(&r) {
                    // Ingest validation happens against track state, so its
                    // failures are client errors, not daemon bugs.
                    Ok(j) => (200, j),
                    Err(e) => (400, protocol::error_response(&format!("{e:#}"))),
                },
                Err(e) => (400, protocol::error_response(&format!("{e:#}"))),
            }
        }
        ("POST", "/v1/shutdown") => {
            stop.store(true, Ordering::SeqCst);
            let mut o = Json::obj();
            o.set("ok", Json::from(true)).set("stopping", Json::from(true));
            (200, o)
        }
        (_, "/healthz" | "/v1/status" | "/v1/select" | "/v1/select_batch" | "/v1/model"
        | "/v1/ingest" | "/v1/shutdown" | "/v1/replicate/manifest" | "/v1/replicate/segment"
        | "/v1/explain" | "/v1/debug/trace") => {
            (405, protocol::error_response("method not allowed"))
        }
        _ => (404, protocol::error_response("no such endpoint")),
    }
}

fn handle_connection(
    advisor: &Advisor,
    mut stream: TcpStream,
    stop: &AtomicBool,
    ctx: RouteContext,
) {
    // Accepted sockets may inherit the listener's nonblocking mode on
    // some platforms; the handler wants plain blocking reads + timeouts.
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    for served in 1..=MAX_REQUESTS_PER_CONN {
        let t_read = Instant::now();
        match read_request(&mut stream, &mut buf) {
            ReadOutcome::Request(mut req) => {
                req.id = obs::next_request_id();
                // One span tree per request, keyed by the id the response
                // echoes as `X-Request-Id` — `GET /v1/debug/trace` joins
                // on it. The parse span is recorded retroactively: the
                // bytes were read before the tree existed.
                let root = trace::root("request", req.id);
                trace::retro_span("parse", t_read.elapsed());
                let o = http_obs();
                let path = req.path.split_once('?').map_or(req.path.as_str(), |(p, _)| p);
                let (requests, latency) = o.route_handles(path);
                requests.inc();
                o.in_flight.add(1.0);
                let timer = obs::timer();
                let keep = req.keep_alive
                    && served < MAX_REQUESTS_PER_CONN
                    && !stop.load(Ordering::SeqCst);
                // `/metrics` is answered here, before the JSON route
                // dispatch: it is the one text/plain endpoint, and — like
                // `/healthz` — it stays open when an auth token is set so
                // scrapers need no credentials.
                let code = if path == "/metrics" {
                    if req.method == "GET" {
                        advisor.publish_obs();
                        let text = obs::global().render();
                        let _respond = trace::span("respond");
                        write_response_raw(
                            &mut stream,
                            200,
                            "text/plain; version=0.0.4; charset=utf-8",
                            text.as_bytes(),
                            keep,
                            Some(req.id),
                        );
                        200
                    } else {
                        let body = protocol::error_response("method not allowed");
                        let _respond = trace::span("respond");
                        write_response(&mut stream, 405, &body, keep, Some(req.id));
                        405
                    }
                } else {
                    let (code, body) = route(advisor, &req, stop, ctx);
                    let respond = trace::span("respond");
                    write_response(&mut stream, code, &body, keep, Some(req.id));
                    drop(respond);
                    code
                };
                root.finish(code);
                o.in_flight.add(-1.0);
                let elapsed_ms = timer.elapsed_s().map(|s| s * 1e3);
                timer.observe(latency);
                let mut fields = vec![
                    ("req", Json::from(req.id)),
                    ("method", Json::from(req.method.as_str())),
                    ("path", Json::from(req.path.as_str())),
                    ("code", Json::from(u64::from(code))),
                ];
                if let Some(ms) = elapsed_ms {
                    fields.push(("ms", Json::from(ms)));
                }
                let level = if code < 400 { olog::Level::Debug } else { olog::Level::Warn };
                olog::log(level, "server", "request", &fields);
                if !keep {
                    return;
                }
            }
            ReadOutcome::Closed => return,
            ReadOutcome::Malformed(code, msg) => {
                let req_id = obs::next_request_id();
                olog::warn(
                    "server",
                    "malformed request",
                    &[
                        ("req", Json::from(req_id)),
                        ("code", Json::from(u64::from(code))),
                        ("error", Json::from(msg.as_str())),
                    ],
                );
                let body = protocol::error_response(&msg);
                write_response(&mut stream, code, &body, false, Some(req_id));
                return;
            }
        }
    }
}

/// The bound daemon. `bind` then `run`; `run` blocks until a
/// `POST /v1/shutdown` lands.
pub struct AdvisorServer {
    listener: TcpListener,
    advisor: Arc<Advisor>,
    workers: usize,
    queue_depth: usize,
    auth_token: Option<String>,
    /// Replica mode: `(primary address, local replica data dir)`.
    replica: Option<(String, std::path::PathBuf)>,
}

impl AdvisorServer {
    pub fn bind(opts: &ServeOptions) -> Result<AdvisorServer> {
        Self::bind_with_store(opts, None)
    }

    /// Bind with an optional durable store: persisted tracks are
    /// recovered before the listener accepts its first connection, and a
    /// clean shutdown snapshots everything back.
    ///
    /// With `opts.replica_of`, the store's root becomes the **replica
    /// data dir**: the advisor is built *without* a store of its own (a
    /// replica never appends — only the puller mutates the dir), any
    /// already-replicated tracks are loaded read-only, and `run` spawns
    /// the background puller alongside the workers.
    pub fn bind_with_store(opts: &ServeOptions, store: Option<TraceStore>) -> Result<AdvisorServer> {
        let mut replica = None;
        let advisor = match &opts.replica_of {
            Some(primary) => {
                let st = store
                    .as_ref()
                    .context("serve --replica-of requires --data-dir for the replicated store")?;
                let root = st.root().to_path_buf();
                let advisor = Advisor::with_store(opts.advisor, None)?;
                let loaded = replicate::load_local_tracks(&advisor, &root)?;
                if loaded > 0 {
                    olog::info(
                        "server",
                        "replica loaded tracks",
                        &[
                            ("tracks", Json::from(loaded)),
                            ("dir", Json::from(format!("{}", root.display()))),
                        ],
                    );
                }
                replica = Some((primary.clone(), root));
                advisor
            }
            None => Advisor::with_store(opts.advisor, store)?,
        };
        let listener = TcpListener::bind(&opts.addr)
            .with_context(|| format!("binding {}", opts.addr))?;
        Ok(AdvisorServer {
            listener,
            advisor: Arc::new(advisor),
            workers: opts.workers.max(1),
            queue_depth: opts.queue_depth.max(1),
            auth_token: opts.auth_token.clone(),
            replica,
        })
    }

    /// The actual bound address (resolves the ephemeral port 0).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    pub fn advisor(&self) -> Arc<Advisor> {
        Arc::clone(&self.advisor)
    }

    /// Serve until shutdown: `workers` handler threads plus one
    /// background re-selection thread, fed by this accept loop through a
    /// bounded queue. Shutdown is a graceful drain: stop queueing (shed
    /// newcomers with `503`), finish every queued and in-flight request,
    /// then snapshot-all.
    pub fn run(self) -> Result<()> {
        self.listener.set_nonblocking(true).context("nonblocking listener")?;
        let stop = AtomicBool::new(false);
        // Connections queued or in a handler; the drain waits on it.
        let active = AtomicUsize::new(0);
        // FIFO: a burst larger than the worker pool must drain in arrival
        // order, not starve the oldest connection. Bounded: past
        // `queue_depth` waiters, newcomers are shed with 503.
        let queue: Mutex<std::collections::VecDeque<TcpStream>> =
            Mutex::new(std::collections::VecDeque::new());
        let ready = Condvar::new();
        let advisor = &self.advisor;
        let ctx = RouteContext {
            auth_token: self.auth_token.as_deref(),
            replica_of: self.replica.as_ref().map(|(p, _)| p.as_str()),
        };

        std::thread::scope(|scope| {
            if let Some((primary, root)) = &self.replica {
                let client = replicate::ReplicaClient {
                    primary: primary.clone(),
                    token: self.auth_token.clone(),
                };
                let root = root.clone();
                let stop = &stop;
                let advisor = Arc::clone(advisor);
                scope.spawn(move || {
                    replicate::run_puller(&advisor, &client, &root, stop);
                });
            }
            for _ in 0..self.workers {
                scope.spawn(|| loop {
                    let conn = {
                        let mut q = queue.lock().unwrap();
                        loop {
                            if let Some(c) = q.pop_front() {
                                break Some(c);
                            }
                            if stop.load(Ordering::SeqCst) {
                                break None;
                            }
                            let (guard, _) =
                                ready.wait_timeout(q, Duration::from_millis(100)).unwrap();
                            q = guard;
                        }
                    };
                    match conn {
                        Some(c) => {
                            handle_connection(advisor, c, &stop, ctx);
                            active.fetch_sub(1, Ordering::SeqCst);
                        }
                        None => break,
                    }
                    http_obs().queue_depth.set(queue.lock().unwrap().len() as f64);
                });
            }
            scope.spawn(|| {
                while !stop.load(Ordering::SeqCst) {
                    if !advisor.run_bg_once() {
                        advisor.maybe_compact();
                        advisor.bg_wait(Duration::from_millis(100));
                    }
                }
            });
            // Accept until the drain completes: after stop, keep running
            // only to shed newcomers while queued + in-flight connections
            // finish.
            loop {
                let draining = stop.load(Ordering::SeqCst);
                if draining && active.load(Ordering::SeqCst) == 0 {
                    break;
                }
                match self.listener.accept() {
                    Ok((stream, _)) => {
                        if draining {
                            shed(stream, "shutting down");
                            continue;
                        }
                        let mut q = queue.lock().unwrap();
                        if q.len() >= self.queue_depth {
                            drop(q);
                            shed(stream, "server saturated; retry");
                            continue;
                        }
                        active.fetch_add(1, Ordering::SeqCst);
                        q.push_back(stream);
                        http_obs().queue_depth.set(q.len() as f64);
                        drop(q);
                        ready.notify_one();
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(e) => {
                        olog::warn(
                            "server",
                            "accept error",
                            &[("error", Json::from(format!("{e}")))],
                        );
                        std::thread::sleep(Duration::from_millis(20));
                    }
                }
            }
            ready.notify_all();
        });
        // All workers have drained: snapshot every persisted track so the
        // next boot replays a compact image instead of a long WAL.
        match self.advisor.persist_all() {
            Ok(0) => {}
            Ok(n) => {
                olog::info("server", "snapshotted tracks on shutdown", &[("tracks", Json::from(n))])
            }
            Err(e) => olog::error(
                "server",
                "shutdown snapshot failed",
                &[("error", Json::from(format!("{e:#}")))],
            ),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_end_detection() {
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\n\r\nrest"), Some(16));
        assert_eq!(find_head_end(b"partial\r\n"), None);
    }

    #[test]
    fn status_lines_cover_every_emitted_code() {
        // Every code routing can produce has an explicit reason phrase —
        // the fallback is reserved for genuinely unknown codes, so a new
        // dispatch arm emitting an unregistered code fails loudly here.
        for &code in EMITTED_CODES {
            assert_ne!(
                status_text(code),
                "Unknown Status",
                "code {code} is emitted by routing but has no explicit reason phrase"
            );
        }
        assert_eq!(status_text(200), "OK");
        assert_eq!(status_text(400), "Bad Request");
        assert_eq!(status_text(401), "Unauthorized");
        assert_eq!(status_text(404), "Not Found");
        assert_eq!(status_text(405), "Method Not Allowed");
        assert_eq!(status_text(408), "Request Timeout");
        assert_eq!(status_text(409), "Conflict");
        assert_eq!(status_text(411), "Length Required");
        assert_eq!(status_text(413), "Payload Too Large");
        assert_eq!(status_text(500), "Internal Server Error");
        assert_eq!(status_text(503), "Service Unavailable");
        // Codes the server never produces hit the explicit fallback
        // instead of masquerading as internal errors (418 used to map to
        // "Internal Server Error" silently).
        assert_eq!(status_text(418), "Unknown Status");
        assert_eq!(status_text(999), "Unknown Status");
        // The response-counter label space matches the same list.
        assert_eq!(EMITTED_CODES.len(), http_obs().responses.len());
    }

    #[test]
    fn try_parse_frames_and_rejects() {
        // Complete frame: parsed, consumed length reported.
        let (req, used) =
            try_parse_request(b"POST /a HTTP/1.1\r\nContent-Length: 2\r\n\r\nhiXX")
                .unwrap()
                .unwrap();
        assert_eq!((req.method.as_str(), req.body.as_str()), ("POST", "hi"));
        assert_eq!(used, b"POST /a HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi".len());

        // Incomplete head and incomplete body both ask for more bytes.
        assert!(try_parse_request(b"POST /a HTT").unwrap().is_none());
        assert!(try_parse_request(b"POST /a HTTP/1.1\r\nContent-Length: 5\r\n\r\nhi")
            .unwrap()
            .is_none());

        // POST without a Content-Length is 411, never read-until-EOF.
        let (code, msg) = try_parse_request(b"POST /a HTTP/1.1\r\n\r\n").unwrap_err();
        assert_eq!(code, 411, "{msg}");
        // ... and so is a chunked body.
        let (code, _) =
            try_parse_request(b"POST /a HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n")
                .unwrap_err();
        assert_eq!(code, 411);
        // GET without a length is fine (empty body).
        assert!(try_parse_request(b"GET /b HTTP/1.1\r\n\r\n").unwrap().is_some());

        // An attacker-controlled Content-Length is rejected before any
        // allocation happens.
        let huge = format!("POST /a HTTP/1.1\r\nContent-Length: {}\r\n\r\n", usize::MAX);
        let (code, _) = try_parse_request(huge.as_bytes()).unwrap_err();
        assert!(code == 413 || code == 400, "huge length must be refused, got {code}");
        let (code, _) = try_parse_request(
            format!("POST /a HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1)
                .as_bytes(),
        )
        .unwrap_err();
        assert_eq!(code, 413);

        // Garbage request lines and unparseable lengths are 400s.
        let (code, _) = try_parse_request(b"\r\n\r\n").unwrap_err();
        assert_eq!(code, 400);
        let (code, _) =
            try_parse_request(b"POST /a HTTP/1.1\r\nContent-Length: x\r\n\r\n").unwrap_err();
        assert_eq!(code, 400);
    }

    #[test]
    fn read_request_parses_connection_semantics_and_pipelining() {
        // Loopback socket pair: the writer side plays the client.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut c = TcpStream::connect(addr).unwrap();
            // Two pipelined requests in one write, then a close request.
            let batch = "POST /a HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi\
                         GET /b HTTP/1.1\r\n\r\n\
                         GET /c HTTP/1.0\r\n\r\n";
            c.write_all(batch.as_bytes()).unwrap();
            // Hold the socket open until the server has read everything.
            let mut sink = [0u8; 16];
            let _ = c.read(&mut sink);
        });
        let (mut stream, _) = listener.accept().unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut buf = Vec::new();

        let ReadOutcome::Request(a) = read_request(&mut stream, &mut buf) else {
            panic!("first request lost")
        };
        assert_eq!((a.method.as_str(), a.path.as_str(), a.body.as_str()), ("POST", "/a", "hi"));
        assert!(a.keep_alive, "HTTP/1.1 defaults to keep-alive");

        let ReadOutcome::Request(b) = read_request(&mut stream, &mut buf) else {
            panic!("pipelined request lost")
        };
        assert_eq!(b.path, "/b");
        assert!(b.keep_alive);

        let ReadOutcome::Request(c) = read_request(&mut stream, &mut buf) else {
            panic!("third request lost")
        };
        assert_eq!(c.path, "/c");
        assert!(!c.keep_alive, "HTTP/1.0 defaults to close");

        drop(stream);
        client.join().unwrap();
    }

    #[test]
    fn read_request_explicit_connection_headers() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut c = TcpStream::connect(addr).unwrap();
            let batch = "GET /x HTTP/1.1\r\nConnection: close\r\n\r\n\
                         GET /y HTTP/1.0\r\nConnection: keep-alive\r\n\r\n";
            c.write_all(batch.as_bytes()).unwrap();
            // Close immediately: the server must still read both buffered
            // requests, then see a clean EOF.
        });
        let (mut stream, _) = listener.accept().unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut buf = Vec::new();
        let ReadOutcome::Request(x) = read_request(&mut stream, &mut buf) else {
            panic!("request lost")
        };
        assert!(!x.keep_alive, "Connection: close must win over the 1.1 default");
        let ReadOutcome::Request(y) = read_request(&mut stream, &mut buf) else {
            panic!("request lost")
        };
        assert!(y.keep_alive, "Connection: keep-alive must win over the 1.0 default");
        // Clean EOF between requests reads as Closed, not Malformed.
        let outcome = read_request(&mut stream, &mut buf);
        assert!(matches!(outcome, ReadOutcome::Closed), "clean EOF must close quietly");
        client.join().unwrap();
    }

    fn req(method: &str, path: &str, body: &str) -> HttpRequest {
        HttpRequest {
            method: method.to_string(),
            path: path.to_string(),
            body: body.to_string(),
            keep_alive: true,
            authorization: None,
            id: obs::next_request_id(),
        }
    }

    #[test]
    fn route_rejects_unknown_and_wrong_method() {
        let advisor = Advisor::new(AdvisorConfig::default());
        let stop = AtomicBool::new(false);
        let ctx = RouteContext::default();
        assert_eq!(route(&advisor, &req("GET", "/nope", ""), &stop, ctx).0, 404);
        assert_eq!(route(&advisor, &req("POST", "/healthz", ""), &stop, ctx).0, 405);
        assert_eq!(route(&advisor, &req("GET", "/v1/select", ""), &stop, ctx).0, 405);
        assert_eq!(route(&advisor, &req("POST", "/v1/select", "{"), &stop, ctx).0, 400);
        assert_eq!(route(&advisor, &req("POST", "/v1/select", "{}"), &stop, ctx).0, 400);
        assert_eq!(route(&advisor, &req("GET", "/v1/select_batch", ""), &stop, ctx).0, 405);
        assert_eq!(route(&advisor, &req("POST", "/v1/select_batch", "{}"), &stop, ctx).0, 400);
        assert_eq!(
            route(&advisor, &req("POST", "/v1/select_batch", r#"{"items": []}"#), &stop, ctx).0,
            400
        );
        // A malformed item 400s naming its index; parsing never runs the
        // model, so this stays cheap.
        let (code, body) = route(
            &advisor,
            &req(
                "POST",
                "/v1/select_batch",
                r#"{"items": [{"system": "system-1/128"}, {"app": "qr"}]}"#,
            ),
            &stop,
            ctx,
        );
        assert_eq!(code, 400);
        assert!(
            body.get("error").unwrap().as_str().unwrap().contains("items[1]"),
            "400 must name the failing index: {body}"
        );
        // Replication endpoints exist (405 on wrong method) but need a
        // store behind them (400 without --data-dir).
        assert_eq!(route(&advisor, &req("POST", "/v1/replicate/manifest", ""), &stop, ctx).0, 405);
        assert_eq!(route(&advisor, &req("GET", "/v1/replicate/manifest", ""), &stop, ctx).0, 400);
        assert_eq!(
            route(&advisor, &req("GET", "/v1/replicate/segment?track=t&name=wal-1.log", ""), &stop, ctx).0,
            400
        );
        // Explain needs an addressing parameter and 404s on unknown keys
        // and tracks; the trace dump is GET-only.
        assert_eq!(route(&advisor, &req("POST", "/v1/explain", ""), &stop, ctx).0, 405);
        assert_eq!(route(&advisor, &req("GET", "/v1/explain", ""), &stop, ctx).0, 400);
        assert_eq!(route(&advisor, &req("GET", "/v1/explain?key=zzz", ""), &stop, ctx).0, 400);
        assert_eq!(
            route(&advisor, &req("GET", "/v1/explain?key=00000000deadbeef", ""), &stop, ctx).0,
            404
        );
        assert_eq!(route(&advisor, &req("GET", "/v1/explain?track=nope", ""), &stop, ctx).0, 404);
        assert_eq!(route(&advisor, &req("POST", "/v1/debug/trace", ""), &stop, ctx).0, 405);
        assert_eq!(
            route(&advisor, &req("GET", "/v1/debug/trace?request_id=x", ""), &stop, ctx).0,
            400
        );
        let (code, dump) = route(&advisor, &req("GET", "/v1/debug/trace", ""), &stop, ctx);
        assert_eq!(code, 200);
        assert!(dump.get("trees").is_some(), "trace dump must carry a trees array: {dump}");
        assert_eq!(route(&advisor, &req("GET", "/healthz", ""), &stop, ctx).0, 200);
        assert!(!stop.load(Ordering::SeqCst));
        assert_eq!(route(&advisor, &req("POST", "/v1/shutdown", ""), &stop, ctx).0, 200);
        assert!(stop.load(Ordering::SeqCst));
    }

    #[test]
    fn auth_token_gates_every_v1_route_but_not_healthz() {
        let advisor = Advisor::new(AdvisorConfig::default());
        let stop = AtomicBool::new(false);
        let ctx = RouteContext { auth_token: Some("s3cret"), replica_of: None };
        // No header, wrong scheme, wrong token: all 401 with a JSON body.
        let (code, body) = route(&advisor, &req("GET", "/v1/status", ""), &stop, ctx);
        assert_eq!(code, 401);
        assert_eq!(body.get("ok").unwrap().as_bool(), Some(false));
        let mut r = req("GET", "/v1/status", "");
        r.authorization = Some("Basic s3cret".to_string());
        assert_eq!(route(&advisor, &r, &stop, ctx).0, 401);
        r.authorization = Some("Bearer wrong".to_string());
        assert_eq!(route(&advisor, &r, &stop, ctx).0, 401);
        // The exact bearer token passes; the health probe never needs it.
        r.authorization = Some("Bearer s3cret".to_string());
        assert_eq!(route(&advisor, &r, &stop, ctx).0, 200);
        assert_eq!(route(&advisor, &req("GET", "/healthz", ""), &stop, ctx).0, 200);
        // The gate runs before dispatch: even unknown paths 401 first.
        assert_eq!(route(&advisor, &req("GET", "/nope", ""), &stop, ctx).0, 401);
        // The debug/explain surfaces are token-gated like every v1 route.
        assert_eq!(route(&advisor, &req("GET", "/v1/explain?key=0", ""), &stop, ctx).0, 401);
        assert_eq!(route(&advisor, &req("GET", "/v1/debug/trace", ""), &stop, ctx).0, 401);
        // Shutdown is token-gated too — the flag must not have flipped.
        assert_eq!(route(&advisor, &req("POST", "/v1/shutdown", ""), &stop, ctx).0, 401);
        assert!(!stop.load(Ordering::SeqCst));
    }

    #[test]
    fn replica_mode_rejects_ingest_with_409_naming_the_primary() {
        let advisor = Advisor::new(AdvisorConfig::default());
        let stop = AtomicBool::new(false);
        let ctx = RouteContext { auth_token: None, replica_of: Some("127.0.0.1:7743") };
        let body = r#"{"track": "t", "n_procs": 4, "events": []}"#;
        let (code, resp) = route(&advisor, &req("POST", "/v1/ingest", body), &stop, ctx);
        assert_eq!(code, 409);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(resp.get("primary").unwrap().as_str(), Some("127.0.0.1:7743"));
        // Reads still serve.
        assert_eq!(route(&advisor, &req("GET", "/v1/status", ""), &stop, ctx).0, 200);
    }
}
