//! Pull-based WAL replication: the advisor's read-replica subsystem
//! (DESIGN.md §13).
//!
//! The durable store already *is* a replication log — per-track WALs of
//! checksummed frames plus `(gen, covered)` snapshots, replayed
//! bit-identically. This module ships those files between nodes:
//!
//! * **Primary side** — [`manifest_json`] lists every track's snapshot +
//!   WAL segments with lengths, generations, covered positions and
//!   fnv64-per-chunk checksums (served as `GET /v1/replicate/manifest`);
//!   [`segment_json`] range-reads one named segment (`GET
//!   /v1/replicate/segment?track=..&name=..&offset=..`). Both are plain
//!   reads of the data dir — the primary keeps no replica state.
//! * **Replica side** — [`run_puller`] (started by `serve --replica-of`)
//!   repeatedly diffs the remote manifest against the local files,
//!   fetches only the missing suffix of each segment, verifies both the
//!   transport checksum and the manifest checksum, structurally validates
//!   the bytes (`wal::scan_bytes` / `snapshot::decode`), and installs
//!   them atomically (tmp + fsync + rename) through [`StoreIo`] — so the
//!   fault-injection tests can kill every install op and pin that a
//!   replica never holds a torn segment. Installed tracks are reloaded
//!   into the advisor via the read-only replay path
//!   ([`store::replay_readonly`]), which never mutates the replicated
//!   files; bit-identical float replay makes the replica's tracked
//!   selections exact.
//!
//! Failure policy: connection errors and mid-fetch races (the primary
//! compacting a generation away under us) abort the *round*, never the
//! process — the puller re-diffs from a fresh manifest after a capped
//! exponential backoff with jitter. A kill-9'd replica reboots from
//! whatever clean prefix it had installed.

use std::collections::BTreeSet;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use super::Advisor;
use crate::obs::{self, log as olog, trace};
use crate::store::io::{RealIo, StoreError, StoreIo};
use crate::store::{self, encode_track_id, snapshot, wal, TraceStore};
use crate::util::fnv::fnv1a_64;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Registry handles for the replication layer, resolved once
/// (DESIGN.md §14). The per-track lag gauge lives in [`sync_track`]:
/// manifest clean-prefix bytes minus local bytes before the pull, 0 after
/// a successful track sync — the replica e2e pins its convergence.
pub(crate) struct ReplicationObs {
    pub(crate) rounds: Arc<obs::Counter>,
    pub(crate) round_aborts: Arc<obs::Counter>,
    pub(crate) backoff_failures: Arc<obs::Gauge>,
    pub(crate) bytes_pulled: Arc<obs::Counter>,
}

pub(crate) fn replication_obs() -> &'static ReplicationObs {
    static OBS: OnceLock<ReplicationObs> = OnceLock::new();
    OBS.get_or_init(|| {
        let r = obs::global();
        ReplicationObs {
            rounds: r
                .counter("mckpt_replication_rounds_total", "Completed replica catch-up rounds."),
            round_aborts: r.counter(
                "mckpt_replication_round_aborts_total",
                "Replica catch-up rounds aborted by an error.",
            ),
            backoff_failures: r.gauge(
                "mckpt_replication_backoff_failures",
                "Consecutive failed rounds driving the current backoff (0 = healthy).",
            ),
            bytes_pulled: r.counter(
                "mckpt_replication_bytes_pulled_total",
                "Segment bytes fetched from the primary.",
            ),
        }
    })
}

/// Chunk size for manifest checksums. Small enough that a replica resumes
/// an interrupted segment fetch near where it stopped, large enough that
/// a 4 MiB WAL lists in 64 sums.
pub const CHUNK_BYTES: u64 = 64 * 1024;

/// Raw bytes served per `/v1/replicate/segment` response (hex-encoded on
/// the wire, so twice this many body bytes). Larger segments take
/// multiple range fetches.
pub const MAX_SEGMENT_FETCH_BYTES: u64 = 1 << 20;

/// Hard cap on a manifest/segment-response JSON document, so a hostile or
/// confused primary cannot balloon the replica.
const MAX_RESPONSE_BYTES: u64 = 64 << 20;

const POLL_INTERVAL: Duration = Duration::from_millis(250);
const BACKOFF_BASE: Duration = Duration::from_millis(250);
const BACKOFF_CAP: Duration = Duration::from_secs(5);
const IO_TIMEOUT: Duration = Duration::from_secs(10);

fn hex64(v: u64) -> String {
    format!("{v:016x}")
}

/// Typed parse failure for replicated metadata: always a
/// [`StoreError::Corrupt`] (the fuzz target's invariant), never a panic.
fn mal(origin: &str, detail: impl Into<String>) -> anyhow::Error {
    StoreError::corrupt(Path::new(origin), detail).into()
}

fn parse_hex64(origin: &str, s: &str) -> Result<u64> {
    if s.len() != 16 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
        return Err(mal(origin, format!("bad checksum literal '{s}'")));
    }
    u64::from_str_radix(s, 16).map_err(|e| mal(origin, format!("bad checksum literal: {e}")))
}

/// Lowercase hex of a byte slice (segment payload transport encoding —
/// the store's JSON layer has no raw-byte type, and the protocol already
/// ships 64-bit cache keys as hex for the same reason).
pub fn hex_encode(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

pub fn hex_decode(origin: &str, s: &str) -> Result<Vec<u8>> {
    if s.len() % 2 != 0 {
        return Err(mal(origin, "odd-length hex payload"));
    }
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(s.len() / 2);
    for pair in bytes.chunks_exact(2) {
        let hi = (pair[0] as char).to_digit(16); // srclint: allow(no-panic-paths) — chunks_exact(2) guarantees both bytes
        let lo = (pair[1] as char).to_digit(16); // srclint: allow(no-panic-paths) — chunks_exact(2) guarantees both bytes
        match (hi, lo) {
            (Some(h), Some(l)) => out.push(((h << 4) | l) as u8),
            _ => return Err(mal(origin, "non-hex byte in payload")),
        }
    }
    Ok(out)
}

/// Per-chunk fnv64 checksums over `bytes` (the last chunk may be short).
pub fn chunk_sums(bytes: &[u8]) -> Vec<u64> {
    bytes.chunks(CHUNK_BYTES as usize).map(fnv1a_64).collect()
}

/// What a segment name says it is. Only these two shapes are replicable;
/// everything else (traversal attempts included) is a typed error.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegmentKind {
    Snapshot,
    Wal(u64),
}

pub fn parse_segment_name(name: &str) -> Result<SegmentKind> {
    if name == snapshot::SNAPSHOT_FILE {
        return Ok(SegmentKind::Snapshot);
    }
    if let Some(num) = name.strip_prefix("wal-").and_then(|s| s.strip_suffix(".log")) {
        if !num.is_empty() && num.len() <= 20 && num.bytes().all(|b| b.is_ascii_digit()) {
            if let Ok(gen) = num.parse::<u64>() {
                return Ok(SegmentKind::Wal(gen));
            }
        }
    }
    Err(mal(name, "not a replicable segment name"))
}

/// One replicable file as the manifest describes it.
#[derive(Debug, Clone)]
pub struct SegmentMeta {
    pub name: String,
    pub kind: SegmentKind,
    /// Generation: the WAL's own for `wal-*.log`, the covered generation
    /// for the snapshot (what decides which local WALs are obsolete).
    pub gen: u64,
    /// On-disk length at manifest time.
    pub len: u64,
    /// Length of the clean prefix (== `len` for snapshots; a WAL may
    /// carry a transient torn tail mid-append that replicas skip).
    pub valid_len: u64,
    /// fnv64 of the whole file.
    pub fnv64: u64,
    /// fnv64 of the clean prefix — what an installed segment must hash to.
    pub valid_fnv64: u64,
    /// fnv64 per [`CHUNK_BYTES`] chunk of the whole file.
    pub chunks: Vec<u64>,
}

#[derive(Debug, Clone)]
pub struct TrackManifest {
    pub id: String,
    pub encoded: String,
    pub segments: Vec<SegmentMeta>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub chunk_bytes: u64,
    pub tracks: Vec<TrackManifest>,
}

/// Manifest entry for one segment file already read into memory. Shared
/// by the primary's manifest route and the fuzz harness's seed corpus.
pub fn segment_entry_json(name: &str, bytes: &[u8]) -> Result<Json> {
    let kind = parse_segment_name(name)?;
    let mut e = Json::obj();
    e.set("name", Json::from(name))
        .set("len", Json::from(bytes.len()))
        .set("fnv64", Json::from(hex64(fnv1a_64(bytes)).as_str()))
        .set(
            "chunks",
            Json::Arr(chunk_sums(bytes).into_iter().map(|c| Json::from(hex64(c).as_str())).collect()),
        );
    match kind {
        SegmentKind::Snapshot => {
            let snap = snapshot::decode(bytes, Path::new(name))?;
            e.set("kind", Json::from("snapshot"))
                .set("gen", Json::from(snap.gen))
                .set("covered", Json::from(snap.covered))
                .set("valid_len", Json::from(bytes.len()))
                .set("valid_fnv64", Json::from(hex64(fnv1a_64(bytes)).as_str()));
        }
        SegmentKind::Wal(gen) => {
            let scan = wal::scan_bytes(bytes, Path::new(name))?;
            let valid = &bytes[..scan.valid_len as usize];
            e.set("kind", Json::from("wal"))
                .set("gen", Json::from(gen))
                .set("records", Json::from(scan.records.len()))
                .set("valid_len", Json::from(scan.valid_len))
                .set("valid_fnv64", Json::from(hex64(fnv1a_64(valid)).as_str()));
        }
    }
    Ok(e)
}

/// The full `/v1/replicate/manifest` response for a data dir: every
/// track, every replicable segment, checksummed. Read-only — races with
/// concurrent appends at worst list a segment mid-frame, which the
/// `valid_len`/`valid_fnv64` pair already accounts for.
pub fn manifest_json(store: &TraceStore) -> Result<Json> {
    let mut tracks = Json::obj();
    for id in store.track_ids()? {
        let dir = store.track_dir(&id);
        let mut segments: Vec<Json> = Vec::new();
        let snap_path = dir.join(snapshot::SNAPSHOT_FILE);
        match std::fs::read(&snap_path) {
            Ok(bytes) => segments.push(segment_entry_json(snapshot::SNAPSHOT_FILE, &bytes)?),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => return Err(StoreError::io("replicate-manifest-read", &snap_path, e).into()),
        }
        for gen in store::wal_gens(&dir)? {
            let path = store::wal_path(&dir, gen);
            match std::fs::read(&path) {
                Ok(bytes) => {
                    segments.push(segment_entry_json(&format!("wal-{gen}.log"), &bytes)?)
                }
                // Raced a compaction unlink; the next manifest settles it.
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => return Err(StoreError::io("replicate-manifest-read", &path, e).into()),
            }
        }
        let mut tj = Json::obj();
        tj.set("encoded", Json::from(encode_track_id(&id).as_str()))
            .set("segments", Json::Arr(segments));
        tracks.set(&id, tj);
    }
    let mut o = Json::obj();
    o.set("ok", Json::from(true))
        .set("chunk_bytes", Json::from(CHUNK_BYTES))
        .set("tracks", tracks);
    Ok(o)
}

const MANIFEST_ORIGIN: &str = "<replicate-manifest>";
const SEGMENT_ORIGIN: &str = "<replicate-segment>";

fn u64_field(origin: &str, obj: &Json, key: &str) -> Result<u64> {
    let v = obj
        .get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| mal(origin, format!("missing numeric field '{key}'")))?;
    if !(v.is_finite() && v >= 0.0 && v.fract() == 0.0 && v <= 9_007_199_254_740_992.0) {
        return Err(mal(origin, format!("field '{key}' = {v} is not a valid u64")));
    }
    Ok(v as u64)
}

fn str_field<'a>(origin: &str, obj: &'a Json, key: &str) -> Result<&'a str> {
    obj.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| mal(origin, format!("missing string field '{key}'")))
}

fn parse_segment_meta(origin: &str, chunk_bytes: u64, j: &Json) -> Result<SegmentMeta> {
    let name = str_field(origin, j, "name")?;
    if name.len() > 64 {
        return Err(mal(origin, "segment name too long"));
    }
    let kind = parse_segment_name(name)?;
    let kind_str = str_field(origin, j, "kind")?;
    let kind_ok = matches!(
        (kind, kind_str),
        (SegmentKind::Snapshot, "snapshot") | (SegmentKind::Wal(_), "wal")
    );
    if !kind_ok {
        return Err(mal(origin, format!("segment '{name}' claims kind '{kind_str}'")));
    }
    let gen = u64_field(origin, j, "gen")?;
    if let SegmentKind::Wal(g) = kind {
        if g != gen {
            return Err(mal(origin, format!("segment '{name}' claims generation {gen}")));
        }
    }
    let len = u64_field(origin, j, "len")?;
    let valid_len = u64_field(origin, j, "valid_len")?;
    if valid_len > len {
        return Err(mal(origin, format!("segment '{name}': valid_len {valid_len} > len {len}")));
    }
    let fnv = parse_hex64(origin, str_field(origin, j, "fnv64")?)?;
    let valid_fnv = parse_hex64(origin, str_field(origin, j, "valid_fnv64")?)?;
    let chunks_json = j
        .get("chunks")
        .and_then(Json::as_arr)
        .ok_or_else(|| mal(origin, format!("segment '{name}' has no chunk list")))?;
    let want_chunks = len.div_ceil(chunk_bytes);
    if chunks_json.len() as u64 != want_chunks {
        return Err(mal(
            origin,
            format!(
                "segment '{name}': {} chunk sums for {len} bytes (want {want_chunks})",
                chunks_json.len()
            ),
        ));
    }
    let mut chunks = Vec::with_capacity(chunks_json.len());
    for c in chunks_json {
        let s = c.as_str().ok_or_else(|| mal(origin, "non-string chunk sum"))?;
        chunks.push(parse_hex64(origin, s)?);
    }
    Ok(SegmentMeta {
        name: name.to_string(),
        kind,
        gen,
        len,
        valid_len,
        fnv64: fnv,
        valid_fnv64: valid_fnv,
        chunks,
    })
}

/// Validated parse of a manifest document. Every rejection is a typed
/// [`StoreError::Corrupt`] — the replica treats a malformed manifest like
/// a corrupt file, never installs from it, and re-diffs next round.
pub fn parse_manifest(j: &Json) -> Result<Manifest> {
    let o = MANIFEST_ORIGIN;
    if j.get("ok").and_then(Json::as_bool) != Some(true) {
        return Err(mal(o, "manifest is not an ok response"));
    }
    let chunk_bytes = u64_field(o, j, "chunk_bytes")?;
    if !(1..=(16 << 20)).contains(&chunk_bytes) {
        return Err(mal(o, format!("implausible chunk_bytes {chunk_bytes}")));
    }
    let tracks_obj = j
        .get("tracks")
        .and_then(Json::as_obj)
        .ok_or_else(|| mal(o, "missing tracks object"))?;
    let mut tracks = Vec::with_capacity(tracks_obj.len());
    for (id, tj) in tracks_obj {
        let encoded = str_field(o, tj, "encoded")?;
        if encoded != encode_track_id(id) {
            return Err(mal(o, format!("track '{id}' lists a mismatched directory name")));
        }
        let segs = tj
            .get("segments")
            .and_then(Json::as_arr)
            .ok_or_else(|| mal(o, format!("track '{id}' has no segment list")))?;
        if segs.len() > 1024 {
            return Err(mal(o, format!("track '{id}' lists {} segments", segs.len())));
        }
        let mut segments = Vec::with_capacity(segs.len());
        let mut snapshots = 0usize;
        let mut last_wal_gen: Option<u64> = None;
        for sj in segs {
            let seg = parse_segment_meta(o, chunk_bytes, sj)?;
            match seg.kind {
                SegmentKind::Snapshot => {
                    snapshots += 1;
                    if snapshots > 1 {
                        return Err(mal(o, format!("track '{id}' lists two snapshots")));
                    }
                }
                SegmentKind::Wal(g) => {
                    if last_wal_gen.is_some_and(|prev| g <= prev) {
                        return Err(mal(o, format!("track '{id}' WAL gens not ascending")));
                    }
                    last_wal_gen = Some(g);
                }
            }
            segments.push(seg);
        }
        tracks.push(TrackManifest {
            id: id.clone(),
            encoded: encoded.to_string(),
            segments,
        });
    }
    Ok(Manifest { chunk_bytes, tracks })
}

/// One range of one segment, as fetched from the primary. `data` is
/// already hex-decoded and transport-checksummed.
#[derive(Debug, Clone)]
pub struct SegmentChunk {
    pub track: String,
    pub name: String,
    pub offset: u64,
    pub total_len: u64,
    pub data: Vec<u8>,
}

/// Build a `/v1/replicate/segment` response body. Shared by the primary
/// route and the fuzz seed corpus.
pub fn segment_response_json(
    track_enc: &str,
    name: &str,
    offset: u64,
    total_len: u64,
    data: &[u8],
) -> Json {
    let mut o = Json::obj();
    o.set("ok", Json::from(true))
        .set("track", Json::from(track_enc))
        .set("name", Json::from(name))
        .set("offset", Json::from(offset))
        .set("total_len", Json::from(total_len))
        .set("len", Json::from(data.len()))
        .set("fnv64", Json::from(hex64(fnv1a_64(data)).as_str()))
        .set("data", Json::from(hex_encode(data).as_str()));
    o
}

/// Validated parse of a segment response: name re-validated, payload
/// hex-decoded and checked against its transport checksum. Typed
/// [`StoreError::Corrupt`] on any mismatch.
pub fn parse_segment(j: &Json) -> Result<SegmentChunk> {
    let o = SEGMENT_ORIGIN;
    if j.get("ok").and_then(Json::as_bool) != Some(true) {
        return Err(mal(o, "segment response is not ok"));
    }
    let track = str_field(o, j, "track")?;
    if track.len() > 512 {
        return Err(mal(o, "track name too long"));
    }
    let name = str_field(o, j, "name")?;
    parse_segment_name(name)?;
    let offset = u64_field(o, j, "offset")?;
    let total_len = u64_field(o, j, "total_len")?;
    let len = u64_field(o, j, "len")?;
    let hex = str_field(o, j, "data")?;
    if hex.len() as u64 > 2 * MAX_SEGMENT_FETCH_BYTES {
        return Err(mal(o, format!("oversized segment payload ({} hex chars)", hex.len())));
    }
    let data = hex_decode(o, hex)?;
    if data.len() as u64 != len {
        return Err(mal(o, format!("payload is {} bytes, response claims {len}", data.len())));
    }
    if offset.saturating_add(len) > total_len {
        return Err(mal(o, "range extends past total_len"));
    }
    let sum = parse_hex64(o, str_field(o, j, "fnv64")?)?;
    if fnv1a_64(&data) != sum {
        return Err(mal(o, "segment payload failed its transport checksum"));
    }
    Ok(SegmentChunk {
        track: track.to_string(),
        name: name.to_string(),
        offset,
        total_len,
        data,
    })
}

/// Serve one segment range from the data dir (the primary's
/// `/v1/replicate/segment` route). `track` is the *encoded* directory
/// name as listed in the manifest; it must round-trip through the track
/// id codec, which confines it to the store's own layout (no traversal).
pub fn segment_json(store: &TraceStore, track_enc: &str, name: &str, offset: u64) -> Result<Json> {
    let id = store::decode_track_id(track_enc).context("bad track parameter")?;
    ensure!(encode_track_id(&id) == track_enc, "non-canonical track parameter");
    parse_segment_name(name)?;
    let path = store.track_dir(&id).join(name);
    let bytes = std::fs::read(&path)
        .map_err(|e| StoreError::io("replicate-segment-read", &path, e))?;
    let total = bytes.len() as u64;
    let start = offset.min(total) as usize;
    let end = offset.saturating_add(MAX_SEGMENT_FETCH_BYTES).min(total) as usize;
    Ok(segment_response_json(track_enc, name, start as u64, total, &bytes[start..end]))
}

/// Validate segment bytes exactly as the install path will: structural
/// decode, returning the installable (clean-prefix) length. The fuzz
/// target drives mutated bytes straight in here — any outcome other than
/// a clean validation must be a typed [`StoreError`].
pub fn validate_segment_bytes(name: &str, bytes: &[u8]) -> Result<u64> {
    match parse_segment_name(name)? {
        SegmentKind::Snapshot => {
            snapshot::decode(bytes, Path::new(name))?;
            Ok(bytes.len() as u64)
        }
        SegmentKind::Wal(_) => {
            let scan = wal::scan_bytes(bytes, Path::new(name))?;
            if scan.torn() {
                return Err(mal(name, "refusing to install torn WAL bytes"));
            }
            if scan.valid_len < wal::WAL_MAGIC.len() as u64 {
                return Err(mal(name, "WAL bytes have no clean prefix"));
            }
            Ok(scan.valid_len)
        }
    }
}

/// Atomically install one verified segment into a track dir: validate
/// structurally, write to `<name>.tmp`, fsync, rename into place. Every
/// file operation goes through `io`, so [`crate::store::FaultIo`] can
/// kill any of them — a failed install leaves the previous file (or no
/// file) intact, never a torn one; a stray `.tmp` is inert (neither
/// replay nor verify reads it) and is overwritten by the next attempt.
pub fn install_segment(io: &dyn StoreIo, dir: &Path, name: &str, bytes: &[u8]) -> Result<()> {
    let keep = validate_segment_bytes(name, bytes)? as usize;
    std::fs::create_dir_all(dir).map_err(|e| StoreError::io("replicate-install-dir", dir, e))?;
    let tmp = dir.join(format!("{name}.tmp"));
    let dest = dir.join(name);
    let written = (|| -> Result<()> {
        let mut f = io
            .create(&tmp)
            .map_err(|e| StoreError::io("replicate-install-create", &tmp, e))?;
        // srclint: allow(no-panic-paths) — validate_segment_bytes caps keep at bytes.len()
        f.write_all(&bytes[..keep])
            .map_err(|e| StoreError::io("replicate-install-write", &tmp, e))?;
        f.sync_all().map_err(|e| StoreError::io("replicate-install-sync", &tmp, e))?;
        drop(f);
        io.rename(&tmp, &dest)
            .map_err(|e| StoreError::io("replicate-install-rename", &tmp, e))?;
        Ok(())
    })();
    if written.is_err() {
        let _ = io.remove_file(&tmp);
        return written;
    }
    // Best effort, like the store's own compaction: a lost dir entry only
    // re-runs an idempotent install next round.
    let _ = io.sync_dir(dir);
    Ok(())
}

/// HTTP pull client for the replication endpoints. Plain HTTP/1.1 over
/// `TcpStream` with `Connection: close` per request — catch-up rounds are
/// rare enough that connection reuse isn't worth the state.
pub struct ReplicaClient {
    /// Primary address, `host:port` (an `http://` prefix is tolerated).
    pub primary: String,
    /// Bearer token forwarded as `Authorization` when the primary
    /// requires `--auth-token`.
    pub token: Option<String>,
}

impl ReplicaClient {
    pub fn addr(&self) -> &str {
        self.primary.trim_start_matches("http://").trim_end_matches('/')
    }

    fn get_json(&self, path_query: &str) -> Result<Json> {
        let addr = self.addr();
        let mut stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to primary {addr}"))?;
        stream.set_read_timeout(Some(IO_TIMEOUT))?;
        stream.set_write_timeout(Some(IO_TIMEOUT))?;
        let auth = match &self.token {
            Some(t) => format!("Authorization: Bearer {t}\r\n"),
            None => String::new(),
        };
        let req = format!(
            "GET {path_query} HTTP/1.1\r\nHost: {addr}\r\n{auth}Connection: close\r\n\r\n"
        );
        stream.write_all(req.as_bytes()).context("sending replicate request")?;
        let mut raw = Vec::new();
        stream
            .take(MAX_RESPONSE_BYTES)
            .read_to_end(&mut raw)
            .context("reading replicate response")?;
        let text = String::from_utf8_lossy(&raw);
        let Some((head, body)) = text.split_once("\r\n\r\n") else {
            bail!("malformed response from primary {addr} (no header terminator)");
        };
        let status = head.lines().next().unwrap_or_default();
        let code = status.split_whitespace().nth(1).unwrap_or_default();
        if code != "200" {
            let snippet: String = body.chars().take(200).collect();
            bail!("primary {addr} answered {status}: {snippet}");
        }
        Json::parse(body)
            .map_err(|e| anyhow::anyhow!("primary {addr} sent unparseable JSON: {e}"))
    }

    pub fn fetch_manifest(&self) -> Result<Manifest> {
        parse_manifest(&self.get_json("/v1/replicate/manifest")?)
    }

    pub fn fetch_segment(&self, track_enc: &str, name: &str, offset: u64) -> Result<SegmentChunk> {
        let j = self.get_json(&format!(
            "/v1/replicate/segment?track={track_enc}&name={name}&offset={offset}"
        ))?;
        let seg = parse_segment(&j)?;
        ensure!(
            seg.track == track_enc && seg.name == name && seg.offset == offset,
            "segment response answers a different request ({}/{} @ {})",
            seg.track,
            seg.name,
            seg.offset
        );
        Ok(seg)
    }
}

/// Bring one local segment up to the manifest's clean prefix. Fetches
/// only the suffix past the longest whole-chunk prefix that still matches
/// the manifest's chunk sums; verifies the assembled bytes against
/// `valid_fnv64` before installing. Returns whether anything changed.
fn sync_segment(
    client: &ReplicaClient,
    io: &dyn StoreIo,
    dir: &Path,
    chunk_bytes: u64,
    track_enc: &str,
    seg: &SegmentMeta,
) -> Result<bool> {
    let local_path = dir.join(&seg.name);
    let local = match std::fs::read(&local_path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
        Err(e) => return Err(StoreError::io("replicate-local-read", &local_path, e).into()),
    };
    if local.len() as u64 == seg.valid_len && fnv1a_64(&local) == seg.valid_fnv64 {
        return Ok(false);
    }
    // Longest prefix of whole chunks on which we already agree.
    let cb = chunk_bytes as usize;
    let mut keep = 0usize;
    for (k, sum) in seg.chunks.iter().enumerate() {
        let end = match (k + 1).checked_mul(cb) {
            Some(e) if e <= local.len() && e as u64 <= seg.valid_len => e,
            _ => break,
        };
        if fnv1a_64(&local[k * cb..end]) != *sum {
            break;
        }
        keep = end;
    }
    let mut candidate = local[..keep].to_vec();
    while (candidate.len() as u64) < seg.valid_len {
        let part = client.fetch_segment(track_enc, &seg.name, candidate.len() as u64)?;
        // The primary compacted or rolled the file out from under the
        // manifest we diffed against: abort the round, re-diff fresh.
        ensure!(
            !part.data.is_empty() && part.total_len >= seg.valid_len,
            "segment {} changed on the primary mid-fetch",
            seg.name
        );
        let want = (seg.valid_len - candidate.len() as u64) as usize;
        let take = part.data.len().min(want);
        replication_obs().bytes_pulled.add(take as u64);
        candidate.extend_from_slice(&part.data[..take]);
    }
    ensure!(
        fnv1a_64(&candidate) == seg.valid_fnv64,
        "segment {} failed its manifest checksum after assembly (primary moved on?)",
        seg.name
    );
    install_segment(io, dir, &seg.name, &candidate)?;
    Ok(true)
}

fn sync_track(
    client: &ReplicaClient,
    io: &dyn StoreIo,
    root: &Path,
    chunk_bytes: u64,
    track: &TrackManifest,
) -> Result<bool> {
    let dir = root.join("tracks").join(&track.encoded);
    let mut changed = false;
    // Lag before this pull: manifest clean-prefix bytes not yet on disk
    // locally. Converges to 0 once every segment below is installed.
    let lag: u64 = track
        .segments
        .iter()
        .map(|s| {
            let local = std::fs::metadata(dir.join(&s.name)).map(|m| m.len()).unwrap_or(0);
            s.valid_len.saturating_sub(local)
        })
        .sum();
    let lag_gauge = obs::global().gauge_with(
        "mckpt_replication_lag_bytes",
        "Manifest bytes not yet replicated locally, per track.",
        &[("track", track.id.as_str())],
    );
    lag_gauge.set(lag as f64);
    // Snapshot first: once it lands, every WAL generation below it is
    // replay-covered, so any intermediate crash state is a consistent
    // prefix of the primary's history.
    let mut ordered: Vec<&SegmentMeta> = track.segments.iter().collect();
    ordered.sort_by_key(|s| match s.kind {
        SegmentKind::Snapshot => (0, s.gen),
        SegmentKind::Wal(g) => (1, g),
    });
    for seg in &ordered {
        if sync_segment(client, io, &dir, chunk_bytes, &track.encoded, seg)? {
            changed = true;
        }
    }
    // Drop local generations the primary has compacted away.
    let snap_gen = ordered.iter().find_map(|s| match s.kind {
        SegmentKind::Snapshot => Some(s.gen),
        SegmentKind::Wal(_) => None,
    });
    if let Some(snap_gen) = snap_gen {
        let remote: BTreeSet<u64> = ordered
            .iter()
            .filter_map(|s| match s.kind {
                SegmentKind::Wal(g) => Some(g),
                SegmentKind::Snapshot => None,
            })
            .collect();
        if dir.is_dir() {
            for gen in store::wal_gens(&dir)? {
                if gen < snap_gen && !remote.contains(&gen) {
                    if io.remove_file(&store::wal_path(&dir, gen)).is_ok() {
                        changed = true;
                    }
                }
            }
        }
    }
    lag_gauge.set(0.0);
    Ok(changed)
}

/// One full catch-up pass: fetch the manifest, bring every listed track's
/// files up to it. Returns `(track id, changed)` for every manifest
/// track. Any error aborts the pass (the caller backs off and re-diffs);
/// everything already installed stays — installs are atomic and ordered
/// so every intermediate state is a consistent prefix.
pub fn sync_once(
    client: &ReplicaClient,
    io: &dyn StoreIo,
    root: &Path,
) -> Result<Vec<(String, bool)>> {
    let manifest = client.fetch_manifest()?;
    let mut out = Vec::with_capacity(manifest.tracks.len());
    for track in &manifest.tracks {
        let changed = sync_track(client, io, root, manifest.chunk_bytes, track)?;
        out.push((track.id.clone(), changed));
    }
    Ok(out)
}

/// Reload one track from its replicated files into the advisor, via the
/// read-only replay path (never mutates the files — a normal open would
/// roll a generation the primary doesn't have).
pub fn reload_track(advisor: &Advisor, root: &Path, id: &str) -> Result<()> {
    let dir = root.join("tracks").join(encode_track_id(id));
    let (state, _torn, problems) = store::replay_readonly(&dir)?;
    for p in &problems {
        let fields = [("track", Json::from(id)), ("problem", Json::from(p.as_str()))];
        olog::warn("replica", "replay problem in replicated track", &fields);
    }
    let state = state
        .with_context(|| format!("no recoverable state in {}", dir.display()))?;
    advisor.install_replica_track(id, state)
}

/// Boot-time load of every locally replicated track (reboot recovery: a
/// kill-9'd replica resumes from whatever clean prefix it installed).
/// Per-track problems are logged, not fatal — the puller re-fetches.
pub fn load_local_tracks(advisor: &Advisor, root: &Path) -> Result<usize> {
    let store = TraceStore::open(root)?;
    let mut loaded = 0usize;
    for id in store.track_ids()? {
        match reload_track(advisor, root, &id) {
            Ok(()) => loaded += 1,
            Err(e) => {
                let err = Json::from(format!("{e:#}"));
                let fields = [("track", Json::from(id.as_str())), ("error", err)];
                olog::error("replica", "boot load of replicated track failed", &fields);
            }
        }
    }
    Ok(loaded)
}

/// Capped exponential backoff with jitter: 0.25 s · 2^(failures-1), capped
/// at 5 s, scaled by a uniform [0.5, 1.5) factor so a replica fleet never
/// retries in lockstep.
pub fn backoff_delay(failures: u32, rng: &mut Rng) -> Duration {
    let exp = failures.saturating_sub(1).min(16);
    let base = BACKOFF_BASE.as_secs_f64() * 2f64.powi(exp as i32);
    Duration::from_secs_f64(base.min(BACKOFF_CAP.as_secs_f64()) * (0.5 + rng.f64()))
}

fn sleep_interruptible(stop: &AtomicBool, total: Duration) {
    let deadline = Instant::now() + total;
    while !stop.load(Ordering::SeqCst) {
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            break;
        }
        std::thread::sleep(left.min(Duration::from_millis(50)));
    }
}

/// The replica's background catch-up loop (one thread inside the serve
/// scope). Never exits on error: failed rounds back off exponentially
/// (with jitter) and re-diff from a fresh manifest; `stop` is the serve
/// loop's shutdown flag.
pub fn run_puller(advisor: &Advisor, client: &ReplicaClient, root: &Path, stop: &AtomicBool) {
    let io = RealIo;
    let mut rng = Rng::new(0x5EED_u64 ^ fnv1a_64(client.primary.as_bytes()));
    let mut failures: u32 = 0;
    while !stop.load(Ordering::SeqCst) {
        // Each catch-up round is its own span tree in the trace ring —
        // the puller has no HTTP request, so it mints a request id of its
        // own; a failed round finishes with a synthetic 500 status so the
        // errors-and-slow sampler keeps it.
        let span = trace::root("replication_round", obs::next_request_id());
        match sync_once(client, &io, root) {
            Ok(tracks) => {
                failures = 0;
                let o = replication_obs();
                o.rounds.inc();
                o.backoff_failures.set(0.0);
                span.attr("tracks", tracks.len() as u64);
                for (id, changed) in tracks {
                    if changed || !advisor.has_track(&id) {
                        if let Err(e) = reload_track(advisor, root, &id) {
                            let err = Json::from(format!("{e:#}"));
                            let fields = [("track", Json::from(id.as_str())), ("error", err)];
                            olog::error("replica", "reload of replicated track failed", &fields);
                        }
                    }
                }
                span.finish(200);
                sleep_interruptible(stop, POLL_INTERVAL);
            }
            Err(e) => {
                failures = failures.saturating_add(1);
                let o = replication_obs();
                o.round_aborts.inc();
                o.backoff_failures.set(failures as f64);
                let delay = backoff_delay(failures, &mut rng);
                let fields = [
                    ("primary", Json::from(client.primary.as_str())),
                    ("attempt", Json::from(failures as f64)),
                    ("retry_in_s", Json::from(delay.as_secs_f64())),
                    ("error", Json::from(format!("{e:#}"))),
                ];
                olog::warn("replica", "catch-up round failed", &fields);
                span.finish(500);
                sleep_interruptible(stop, delay);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::{TrackState, WalRecord};

    fn tmp(tag: &str) -> PathBuf {
        static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let n = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        std::env::temp_dir().join(format!("mckpt-repl-{tag}-{}-{n}", std::process::id()))
    }

    fn wal_bytes(recs: &[WalRecord]) -> Vec<u8> {
        let mut b = wal::WAL_MAGIC.to_vec();
        for r in recs {
            b.extend_from_slice(&wal::encode_frame(r));
        }
        b
    }

    #[test]
    fn hex_roundtrip_and_rejections() {
        let bytes = [0u8, 1, 0x7f, 0xff, 0xab];
        assert_eq!(hex_decode("t", &hex_encode(&bytes)).unwrap(), bytes);
        assert!(hex_decode("t", "abc").is_err(), "odd length");
        assert!(hex_decode("t", "zz").is_err(), "non-hex");
        assert_eq!(parse_hex64("t", &hex64(0xdead_beef_0102_0304)).unwrap(), 0xdead_beef_0102_0304);
        assert!(parse_hex64("t", "dead").is_err(), "short literal");
    }

    #[test]
    fn segment_names_are_strictly_validated() {
        assert_eq!(parse_segment_name("snapshot.bin").unwrap(), SegmentKind::Snapshot);
        assert_eq!(parse_segment_name("wal-7.log").unwrap(), SegmentKind::Wal(7));
        for bad in [
            "../snapshot.bin",
            "wal-.log",
            "wal-7.log.tmp",
            "snapshot.tmp",
            "wal-x.log",
            "wal-99999999999999999999999.log",
            "",
        ] {
            let err = parse_segment_name(bad).unwrap_err();
            assert!(err.downcast_ref::<StoreError>().is_some(), "untyped error for '{bad}'");
        }
    }

    #[test]
    fn manifest_roundtrips_through_parse() {
        let recs = [
            WalRecord::Create { n_procs: 3 },
            WalRecord::Outage { proc: 0, fail: 10.0, repair: 20.0 },
            WalRecord::Refit { lambda: 2.5e-6, theta: 1.0e-3 },
        ];
        let bytes = wal_bytes(&recs);
        let entry = segment_entry_json("wal-4.log", &bytes).unwrap();
        let mut tj = Json::obj();
        tj.set("encoded", Json::from(encode_track_id("a/b").as_str()))
            .set("segments", Json::Arr(vec![entry]));
        let mut tracks = Json::obj();
        tracks.set("a/b", tj);
        let mut doc = Json::obj();
        doc.set("ok", Json::from(true))
            .set("chunk_bytes", Json::from(CHUNK_BYTES))
            .set("tracks", tracks);

        let m = parse_manifest(&doc).unwrap();
        assert_eq!(m.chunk_bytes, CHUNK_BYTES);
        assert_eq!(m.tracks.len(), 1);
        let t = &m.tracks[0];
        assert_eq!((t.id.as_str(), t.encoded.as_str()), ("a/b", "a%2Fb"));
        let seg = &t.segments[0];
        assert_eq!(seg.kind, SegmentKind::Wal(4));
        assert_eq!(seg.len, bytes.len() as u64);
        assert_eq!(seg.valid_len, seg.len, "clean WAL has no torn tail");
        assert_eq!(seg.fnv64, fnv1a_64(&bytes));
        assert_eq!(seg.chunks, chunk_sums(&bytes));

        // Tampering with any field is a typed rejection.
        let mut bad = doc.clone();
        bad.set("chunk_bytes", Json::from(0u64));
        assert!(parse_manifest(&bad).unwrap_err().downcast_ref::<StoreError>().is_some());
        assert!(parse_manifest(&Json::obj()).is_err());
    }

    #[test]
    fn segment_response_roundtrips_and_checks_payload() {
        let data = wal_bytes(&[WalRecord::Create { n_procs: 2 }]);
        let j = segment_response_json("c1", "wal-1.log", 0, data.len() as u64, &data);
        let seg = parse_segment(&j).unwrap();
        assert_eq!((seg.track.as_str(), seg.name.as_str(), seg.offset), ("c1", "wal-1.log", 0));
        assert_eq!(seg.data, data);

        let mut forged = j.clone();
        forged.set("fnv64", Json::from(hex64(0).as_str()));
        let err = parse_segment(&forged).unwrap_err();
        assert!(err.downcast_ref::<StoreError>().is_some(), "forged checksum must be typed");
    }

    #[test]
    fn install_rejects_garbage_and_lands_clean_segments() {
        let dir = tmp("install");
        let io = RealIo;

        // Garbage never lands, and never leaves a file behind.
        let err = install_segment(&io, &dir, "wal-1.log", b"not a wal at all").unwrap_err();
        assert!(err.downcast_ref::<StoreError>().is_some());
        assert!(!dir.join("wal-1.log").exists());

        // A torn WAL image is refused outright (the puller only assembles
        // verified clean prefixes, so reaching install with torn bytes
        // means the source lied).
        let mut torn = wal_bytes(&[WalRecord::Create { n_procs: 2 }]);
        torn.extend_from_slice(&[9, 9, 9]);
        assert!(install_segment(&io, &dir, "wal-1.log", &torn).is_err());

        let good = wal_bytes(&[
            WalRecord::Create { n_procs: 2 },
            WalRecord::Outage { proc: 1, fail: 5.0, repair: 6.0 },
        ]);
        install_segment(&io, &dir, "wal-1.log", &good).unwrap();
        assert_eq!(std::fs::read(dir.join("wal-1.log")).unwrap(), good);

        let mut state = TrackState::new(2).unwrap();
        state.apply(&WalRecord::Outage { proc: 0, fail: 1.0, repair: 2.0 }).unwrap();
        let snap = snapshot::encode(1, 2, &state);
        install_segment(&io, &dir, "snapshot.bin", &snap).unwrap();
        let (replayed, torn_tail, problems) = store::replay_readonly(&dir).unwrap();
        assert!(!torn_tail && problems.is_empty(), "{problems:?}");
        let replayed = replayed.unwrap();
        // Snapshot gen 1 covers 2 records of wal-1: exactly one outage
        // replays on top of the snapshotted one.
        assert_eq!(replayed.accepted, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn backoff_is_capped_and_jittered() {
        let mut rng = Rng::new(7);
        for failures in 1..40u32 {
            let d = backoff_delay(failures, &mut rng).as_secs_f64();
            assert!(d >= 0.25 * 0.5 - 1e-12, "attempt {failures}: {d}");
            assert!(d < 5.0 * 1.5 + 1e-12, "attempt {failures}: {d}");
        }
        // First retry is fast, deep retries hug the cap.
        let mut rng = Rng::new(8);
        let first = backoff_delay(1, &mut rng).as_secs_f64();
        assert!(first < 0.25 * 1.5 + 1e-12);
        let deep = backoff_delay(30, &mut rng).as_secs_f64();
        assert!(deep >= 5.0 * 0.5 - 1e-12);
    }
}
