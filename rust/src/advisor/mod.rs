//! The **advisor service** — a long-running interval-recommendation
//! daemon: the paper's offline "which checkpointing interval maximizes
//! UWT" question (§VI) answered continuously, for many systems, under
//! drifting failure rates. This is the first Layer-4 subsystem of the
//! ROADMAP: everything below it (the spectral probe engine, the
//! warm-startable builders, the trace index, the rate fitting) already
//! existed as one-shot machinery; this module keeps it alive.
//!
//! * [`protocol`] — hand-rolled JSON wire schema (`select`,
//!   `select_batch`, `model`, `ingest`, `status`), idiom-matching
//!   `util::json`/`util::cli`;
//! * [`cache`] — the sharded concurrent recommendation cache: builders
//!   keyed by a canonical spec hash, LRU-evicted under a memory budget,
//!   repeat hits answered in O(1) without touching the model layer;
//! * [`ingest`] — streaming failure ingestion per tracked system into an
//!   appendable [`crate::traces::index::TraceTail`], with windowed
//!   least-squares MTTF/MTTR re-fits;
//! * [`server`] — the `std::net::TcpListener` HTTP/1.1 front end (with
//!   keep-alive connections) and the `malleable-ckpt serve` subcommand.
//!
//! Selection misses resolve through the batch-first facade
//! ([`crate::api::SelectBatch`]): `/v1/select` is a one-spec batch, and
//! `/v1/select_batch` amortizes one HTTP round trip over many systems —
//! per-item cache lookups and tracked-rate resolution first, then every
//! miss fans out through one deduped batch (identical specs build once)
//! whose canonical hashes are, by shared definition, the cache keys.
//!
//! With `serve --data-dir`, every track is durably backed by
//! [`crate::store`]: each accepted outage, rate re-fit, registered
//! recommendation and retention eviction appends to the track's WAL
//! under the track's own lock (so the log order equals the apply order),
//! the background thread compacts oversized WALs into snapshots, a clean
//! shutdown snapshots everything, and boot replays whatever the last
//! process left behind — including a torn tail from `kill -9`. The
//! optional `--max-events` retention cap evicts whole
//! `--retention-days`-wide windows from the oldest end of a tail (never
//! the newest window), logged so replay reproduces the surviving state
//! exactly.
//!
//! ## Drift semantics
//!
//! A `select` request carrying a `track` id registers its spec under that
//! track and is answered with the track's **current** re-fitted rates
//! substituted for the request's. Every accepted `ingest` batch re-fits
//! the window; when the re-fit moves beyond the configured relative
//! threshold against the rates a registered recommendation was computed
//! with (`max(|λ̂/λ−1|, |θ̂/θ−1|) > drift_threshold`), the advisor marks
//! the cache entry stale and **re-selects in the background**, seeding
//! the new builder's stationary solve with the previous recommendation's
//! last probe π ([`crate::markov::SharedBuilder::seed_pi`]) — the
//! spectral probe engine's warm starts amortize across the daemon's
//! lifetime, not just one search. Until the re-selection lands, `select`
//! keeps serving the stale entry (flagged `"stale": true`); afterwards
//! the track's registration points at the new key and the stale entry is
//! dropped.
//!
//! The threshold cuts both ways: **sub-threshold** rate jitter from
//! routine ingest batches does *not* re-key a tracked request either — a
//! registered recommendation keeps serving from its existing cache entry
//! until the drift is large enough to refresh it, so actively-ingesting
//! tracks still get O(1) repeat hits, and the drift reference always
//! describes the rates the served recommendation was *built* with (a
//! crept baseline can never silently absorb slow drift).
//!
//! Concurrency: the track map itself is locked only long enough to clone
//! a per-track `Arc<Mutex<Track>>` handle — ingest splices and re-fits
//! run under the individual track's lock, so a heavy batch for one
//! system never stalls requests for another (the cache is sharded for
//! the same reason).

pub mod cache;
pub mod ingest;
pub mod protocol;
pub mod replicate;
pub mod server;

use std::collections::hash_map::Entry;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::api::{self, SelectSpec};
use crate::markov::{BuildOptions, ModelInputs, SharedBuilder};
use crate::obs::{self, log as olog, trace};
use crate::runtime::ComputeEngine;
use crate::search::{select_interval_shared_traced, SearchConfig};
use crate::store::{SpecRecord, TraceStore, TrackState};
use crate::util::json::Json;

use self::cache::{canonical_key, CacheEntry, ShardedCache};
use self::ingest::{relative_drift, Track, TrackedSpec};
use self::protocol::{key_hex, select_response, IngestRequest, ModelRequest, SelectRequest};

/// Daemon tuning knobs (all exposed as `serve` flags).
#[derive(Debug, Clone, Copy)]
pub struct AdvisorConfig {
    /// Independently locked cache shards.
    pub shards: usize,
    /// Memory budget for the recommendation cache, bytes.
    pub cache_bytes: usize,
    /// Relative rate drift that invalidates a recommendation.
    pub drift_threshold: f64,
    /// Re-fit window over the ingested tail, seconds.
    pub refit_window: f64,
    /// Minimum failures inside the window before a re-fit is trusted.
    pub min_refit_failures: usize,
    /// Per-track event-retention cap (0 = unlimited): past it, whole
    /// retention windows are evicted from the oldest end of the tail.
    pub max_events: usize,
    /// Width of the retention/shard windows eviction rides on, seconds.
    pub retention_window: f64,
}

impl Default for AdvisorConfig {
    fn default() -> Self {
        AdvisorConfig {
            shards: 8,
            cache_bytes: 256 << 20,
            drift_threshold: 0.10,
            refit_window: 30.0 * 86_400.0,
            min_refit_failures: 8,
            max_events: 0,
            retention_window: 7.0 * 86_400.0,
        }
    }
}

/// One queued background re-selection.
struct BgJob {
    track: String,
    old_key: u64,
    /// Inputs with the re-fitted rates already substituted.
    inputs: ModelInputs,
    cfg: SearchConfig,
    /// The pre-drift builder's last probe π.
    seed: Option<Vec<f64>>,
    /// The spec's drift reference before this job was cut — restored on
    /// failure so the next ingest re-detects the drift and retries.
    prev_rates: (f64, f64),
}

type TrackHandle = Arc<Mutex<Track>>;

/// The daemon's shared state: every HTTP worker holds an `Arc<Advisor>`.
pub struct Advisor {
    cfg: AdvisorConfig,
    cache: ShardedCache,
    /// Track registry. The map lock is held only to clone a handle;
    /// per-track work runs under the track's own lock.
    tracks: Mutex<HashMap<String, TrackHandle>>,
    /// Durable backing (`serve --data-dir`): new tracks open their
    /// per-track WAL here; `None` keeps the PR 3 in-memory behavior.
    store: Option<TraceStore>,
    bg: Mutex<VecDeque<BgJob>>,
    bg_cv: Condvar,
    started: Instant,
    /// Request/background counters are [`obs::Counter`]s owned by the
    /// instance (so `/v1/status` stays exact per advisor — tests build
    /// many advisors in one process) and mirrored into the process-global
    /// registry by [`Advisor::publish_obs`] via `set_max`.
    selects: obs::Counter,
    select_batches: obs::Counter,
    ingests: obs::Counter,
    models: obs::Counter,
    bg_completed: obs::Counter,
    bg_errors: obs::Counter,
    compactions: obs::Counter,
    /// Rate limiter for the background compaction sweep.
    last_compact_check: Mutex<Instant>,
}

impl Advisor {
    pub fn new(cfg: AdvisorConfig) -> Advisor {
        Self::with_store(cfg, None).expect("in-memory advisor construction cannot fail")
    }

    /// Build an advisor over an optional durable store, recovering every
    /// persisted track (snapshot + WAL replay, torn tails truncated)
    /// before serving.
    pub fn with_store(cfg: AdvisorConfig, store: Option<TraceStore>) -> Result<Advisor> {
        let advisor = Advisor {
            cache: ShardedCache::new(cfg.shards.max(1), cfg.cache_bytes),
            cfg,
            tracks: Mutex::new(HashMap::new()),
            store,
            bg: Mutex::new(VecDeque::new()),
            bg_cv: Condvar::new(),
            started: Instant::now(),
            selects: obs::Counter::default(),
            select_batches: obs::Counter::default(),
            ingests: obs::Counter::default(),
            models: obs::Counter::default(),
            bg_completed: obs::Counter::default(),
            bg_errors: obs::Counter::default(),
            compactions: obs::Counter::default(),
            last_compact_check: Mutex::new(Instant::now()),
        };
        if let Some(st) = &advisor.store {
            let mut map = advisor.tracks.lock().unwrap();
            for id in st.track_ids()? {
                let (ts, state) = st.open_track(&id, None)?;
                let mut track = track_from_state(state)?;
                track.store = Some(ts);
                map.insert(id, Arc::new(Mutex::new(track)));
            }
        }
        Ok(advisor)
    }

    pub fn config(&self) -> &AdvisorConfig {
        &self.cfg
    }

    /// `true` when tracks persist across restarts.
    pub fn persistent(&self) -> bool {
        self.store.is_some()
    }

    /// The durable store backing this advisor, if any — the replication
    /// manifest/segment endpoints read segments straight from its root.
    pub fn store(&self) -> Option<&TraceStore> {
        self.store.as_ref()
    }

    /// `true` when `track_id` is registered (brief map lock only).
    pub fn has_track(&self, track_id: &str) -> bool {
        self.tracks.lock().unwrap().contains_key(track_id)
    }

    /// Install (or refresh) a track from replicated durable state — the
    /// replica puller's apply path. The rebuilt track carries no store
    /// handle of its own: a replica must never append to the replicated
    /// files (that would diverge them from the primary's history), so
    /// `record_spec`/ingest persistence all no-op and only the puller
    /// mutates the data dir. An existing handle is refreshed in place
    /// under its own lock, so concurrent selects see either the old or
    /// the new state, never a torn mix.
    pub fn install_replica_track(&self, track_id: &str, state: TrackState) -> Result<()> {
        let track = track_from_state(state)?;
        let handle = {
            let mut map = self.tracks.lock().unwrap();
            match map.entry(track_id.to_string()) {
                Entry::Occupied(e) => Arc::clone(e.get()),
                Entry::Vacant(v) => {
                    v.insert(Arc::new(Mutex::new(track)));
                    return Ok(());
                }
            }
        };
        *handle.lock().unwrap() = track;
        Ok(())
    }

    /// Rate-independent identity of a request spec — what ties a track's
    /// registration to "the same request" across drift-driven re-keys.
    fn spec_identity(inputs: &ModelInputs, cfg: &SearchConfig) -> u64 {
        let mut neutral = inputs.clone();
        neutral.system.lambda = 1.0;
        neutral.system.theta = 1.0;
        canonical_key(&neutral, cfg)
    }

    /// Clone the handle of an existing track (brief map lock only).
    fn track_handle(&self, track_id: &str) -> Option<TrackHandle> {
        self.tracks.lock().unwrap().get(track_id).cloned()
    }

    /// Resolve one request to model inputs and cache keys — the shared
    /// front half of `/v1/select` and `/v1/select_batch`: substitute the
    /// track's re-fitted rates, then decide which key the request serves
    /// from. A registered request keeps resolving to its current entry
    /// while a drift re-selection is in flight (the background job owns
    /// the refresh) AND under sub-threshold rate jitter: the threshold
    /// that decides when to refresh also decides when to re-key —
    /// otherwise every routine ingest batch would turn the next select
    /// into a foreground rebuild and a fresh cache entry. Returns
    /// `(inputs, serve_key, fresh_key)`; a miss builds under `fresh_key`.
    fn resolve(&self, req: &SelectRequest) -> Result<(ModelInputs, u64, u64)> {
        let mut system = req.system;
        let handle = req.track.as_deref().and_then(|tid| self.track_handle(tid));
        if let Some(h) = &handle {
            let track = h.lock().unwrap();
            if let Some((l, t)) = track.rates {
                system.lambda = l;
                system.theta = t;
            }
        }
        let inputs = ModelInputs::new(system, &req.app, &req.policy)?;
        let fresh_key = canonical_key(&inputs, &req.cfg);
        let mut key = fresh_key;
        if let Some(h) = &handle {
            let identity = Self::spec_identity(&inputs, &req.cfg);
            let track = h.lock().unwrap();
            if let Some(spec) = track
                .specs
                .iter()
                .find(|s| Self::spec_identity(&s.inputs, &s.cfg) == identity)
            {
                let jitter = relative_drift(spec.rates_used, (system.lambda, system.theta));
                if spec.pending || jitter <= self.cfg.drift_threshold {
                    key = spec.key;
                }
            }
        }
        Ok((inputs, key, fresh_key))
    }

    /// Cache a freshly solved selection and register it under its track;
    /// the shared back half of the select paths.
    fn admit(
        &self,
        req: &SelectRequest,
        inputs: &ModelInputs,
        fresh_key: u64,
        ok: &api::SelectOk,
        insert: bool,
    ) -> Json {
        let (lambda, theta) = (inputs.system.lambda, inputs.system.theta);
        if insert {
            let builder =
                Arc::clone(ok.builder.as_ref().expect("the native facade returns a builder"));
            let bytes = entry_bytes(&builder, ok.search.probes.len());
            self.cache.insert(CacheEntry {
                key: fresh_key,
                builder,
                result: ok.search.clone(),
                trace: Arc::clone(&ok.trace),
                lambda,
                theta,
                bytes,
                stale: false,
            });
        }
        self.register(req.track.as_deref(), fresh_key, inputs, &req.cfg, (lambda, theta));
        select_response(&ok.search, fresh_key, false, lambda, theta, req.track.as_deref(), false)
    }

    /// Answer one `select`: cache hit in O(1); a miss resolves through
    /// the batch facade (a one-spec [`api::SelectBatch`]) and caches the
    /// returned builder alongside the result.
    pub fn select(&self, req: &SelectRequest) -> Result<Json> {
        self.selects.inc();
        // The only instrumentation on the cached hot path: with
        // `serve --no-obs` the timer is disarmed and reads no clock.
        let timer = obs::timer();
        let out = self.select_impl(req);
        timer.observe(&advisor_obs().select_seconds);
        out
    }

    fn select_impl(&self, req: &SelectRequest) -> Result<Json> {
        let (inputs, key, fresh_key) = self.resolve(req)?;
        let hit = {
            let _lookup = trace::span("cache_lookup");
            self.cache.get(key)
        };
        if let Some(entry) = hit {
            // Register with the rates the served entry was computed with:
            // the drift reference must describe the recommendation, not
            // the request.
            self.register(
                req.track.as_deref(),
                key,
                &inputs,
                &req.cfg,
                (entry.lambda, entry.theta),
            );
            return Ok(select_response(
                &entry.result,
                key,
                true,
                entry.lambda,
                entry.theta,
                req.track.as_deref(),
                entry.stale,
            ));
        }
        // Miss: build at the current (possibly re-fitted) rates under the
        // fresh key, whatever registration said.
        let spec = SelectSpec::new(inputs.clone(), req.cfg);
        let ok = api::select_one(spec, &ComputeEngine::native())?;
        Ok(self.admit(req, &inputs, fresh_key, &ok, true))
    }

    /// Answer one `/v1/select_batch`: per-item tracked-rate resolution
    /// and cache lookup first (hits answered O(1)), then every miss fans
    /// out through ONE [`api::SelectBatch`] — identical specs collapse to
    /// a single build — and lands in the cache like a singleton select.
    /// Per-item failures become per-item error objects carrying the item
    /// index; one bad item never poisons the batch.
    pub fn select_batch(&self, reqs: &[SelectRequest]) -> Json {
        self.select_batches.inc();
        self.selects.add(reqs.len() as u64);
        let mut items: Vec<Option<Json>> = (0..reqs.len()).map(|_| None).collect();
        // (item index, resolved inputs, fresh key) of each cache miss.
        let mut misses: Vec<(usize, ModelInputs, u64)> = Vec::new();
        let mut batch = api::SelectBatch::new();
        for (i, req) in reqs.iter().enumerate() {
            match self.resolve(req) {
                Ok((inputs, key, fresh_key)) => {
                    if let Some(entry) = self.cache.get(key) {
                        self.register(
                            req.track.as_deref(),
                            key,
                            &inputs,
                            &req.cfg,
                            (entry.lambda, entry.theta),
                        );
                        items[i] = Some(select_response(
                            &entry.result,
                            key,
                            true,
                            entry.lambda,
                            entry.theta,
                            req.track.as_deref(),
                            entry.stale,
                        ));
                    } else {
                        batch.push(SelectSpec::new(inputs.clone(), req.cfg));
                        misses.push((i, inputs, fresh_key));
                    }
                }
                Err(e) => items[i] = Some(protocol::batch_item_error(i, &format!("{e:#}"))),
            }
        }
        let outcomes = batch.run(&ComputeEngine::native());
        let mut inserted: std::collections::HashSet<u64> = std::collections::HashSet::new();
        for ((i, inputs, fresh_key), outcome) in misses.into_iter().zip(outcomes) {
            debug_assert_eq!(outcome.key, fresh_key, "facade and cache keys diverged");
            items[i] = Some(match &outcome.result {
                // Duplicates share one build; insert its entry once.
                Ok(ok) => self.admit(&reqs[i], &inputs, fresh_key, ok, inserted.insert(fresh_key)),
                Err(e) => protocol::batch_item_error(i, &e.0),
            });
        }
        protocol::select_batch_response(
            items.into_iter().map(|o| o.expect("every item answered")).collect(),
        )
    }

    /// Fetch a track handle, creating the track on first sight. The
    /// creation path (directory setup, WAL creation + fsync when a store
    /// is configured) runs **outside** the map lock — the map lock is
    /// only ever held to look up or insert a handle, so slow disk I/O
    /// for one new track never stalls requests for others. A store
    /// failure degrades to an in-memory track with a visible complaint
    /// rather than failing the request.
    fn track_handle_or_create(&self, tid: &str, n_procs: usize) -> TrackHandle {
        if let Some(h) = self.track_handle(tid) {
            return h;
        }
        let mut track = Track::new(n_procs).expect("n >= 1 by construction");
        if let Some(st) = &self.store {
            match st.open_track(tid, Some(n_procs)) {
                Ok((ts, state)) => match track_from_state(state) {
                    Ok(mut restored) => {
                        restored.store = Some(ts);
                        track = restored;
                    }
                    Err(e) => {
                        let err = Json::from(format!("{e:#}"));
                        let fields = [("track", Json::from(tid)), ("error", err)];
                        olog::error("advisor", "track not restorable", &fields);
                    }
                },
                Err(e) => {
                    let err = Json::from(format!("{e:#}"));
                    let fields = [("track", Json::from(tid)), ("error", err)];
                    olog::error("advisor", "track not persisted", &fields);
                }
            }
        }
        let fresh = Arc::new(Mutex::new(track));
        let mut map = self.tracks.lock().unwrap();
        match map.entry(tid.to_string()) {
            // Lost a creation race: adopt the winner (both opened the
            // same empty WAL with identical Create records, so dropping
            // the duplicate handle is harmless).
            Entry::Occupied(e) => Arc::clone(e.get()),
            Entry::Vacant(v) => Arc::clone(v.insert(fresh)),
        }
    }

    /// Register (or refresh) a spec under a track, creating the track on
    /// first sight with the system's processor count. `rates` is the
    /// drift reference — the rates the recommendation at `key` was
    /// actually computed with.
    fn register(
        &self,
        track_id: Option<&str>,
        key: u64,
        inputs: &ModelInputs,
        cfg: &SearchConfig,
        rates: (f64, f64),
    ) {
        let Some(tid) = track_id else {
            return;
        };
        let handle = self.track_handle_or_create(tid, inputs.system.n);
        let identity = Self::spec_identity(inputs, cfg);
        let mut track = handle.lock().unwrap();
        let changed = match track
            .specs
            .iter_mut()
            .find(|s| Self::spec_identity(&s.inputs, &s.cfg) == identity)
        {
            Some(spec) => {
                if !spec.pending && (spec.key != key || spec.rates_used != rates) {
                    spec.key = key;
                    spec.inputs = inputs.clone();
                    spec.rates_used = rates;
                    true
                } else {
                    false
                }
            }
            None => {
                track.specs.push(TrackedSpec {
                    key,
                    inputs: inputs.clone(),
                    cfg: *cfg,
                    rates_used: rates,
                    pending: false,
                });
                true
            }
        };
        if changed {
            let rec = SpecRecord {
                identity,
                key,
                rates_used: rates,
                refresh: false,
                inputs: inputs.clone(),
                cfg: *cfg,
            };
            if let Err(e) = track.record_spec(rec) {
                let err = Json::from(format!("{e:#}"));
                let fields = [("track", Json::from(tid)), ("error", err)];
                olog::error("advisor", "recommendation not persisted", &fields);
            }
        }
    }

    /// Fold an `ingest` batch into its track, re-fit the window, and
    /// enqueue background re-selections for every registered spec whose
    /// rates drifted beyond the threshold. Only this track's lock is
    /// held across the splice — other tracks stay fully concurrent.
    pub fn ingest(&self, req: &IngestRequest) -> Result<Json> {
        self.ingests.inc();
        let handle = match self.track_handle(&req.track) {
            Some(h) => h,
            None => {
                let n = req
                    .n_procs
                    .context("first ingest for a track must carry 'n_procs'")?;
                anyhow::ensure!(n >= 1, "'n_procs' must be positive");
                self.track_handle_or_create(&req.track, n)
            }
        };
        let mut track = handle.lock().unwrap();
        if let Some(n) = req.n_procs {
            anyhow::ensure!(
                n == track.n_procs,
                "track '{}' has {} processors, request says {n}",
                req.track,
                track.n_procs
            );
        }
        let (accepted, merged) = track.ingest(&req.events)?;
        let refit = track.refit(
            self.cfg.refit_window,
            self.cfg.min_refit_failures,
            self.cfg.retention_window,
        )?;
        let evicted = track.enforce_retention(self.cfg.max_events, self.cfg.retention_window)?;
        let mut enqueued = 0usize;
        if let Some(fresh) = track.rates {
            for spec in &mut track.specs {
                if spec.pending {
                    continue;
                }
                let drift = relative_drift(spec.rates_used, fresh);
                if drift > self.cfg.drift_threshold {
                    let seed = self.cache.mark_stale(spec.key).and_then(|e| e.builder.warm_pi());
                    let mut inputs = spec.inputs.clone();
                    inputs.system.lambda = fresh.0;
                    inputs.system.theta = fresh.1;
                    let job = BgJob {
                        track: req.track.clone(),
                        old_key: spec.key,
                        inputs,
                        cfg: spec.cfg,
                        seed,
                        prev_rates: spec.rates_used,
                    };
                    spec.pending = true;
                    spec.rates_used = fresh;
                    self.bg.lock().unwrap().push_back(job);
                    self.bg_cv.notify_one();
                    enqueued += 1;
                }
            }
        }
        let mut o = Json::obj();
        o.set("ok", Json::from(true))
            .set("track", Json::from(req.track.as_str()))
            .set("accepted", Json::from(accepted))
            .set("merged", Json::from(merged))
            .set("evicted", Json::from(evicted))
            .set("events_total", Json::from(track.tail.n_events()));
        if let Some((l, t)) = track.rates {
            o.set("lambda", Json::from(l)).set("theta", Json::from(t));
        }
        o.set("refit", Json::from(refit.is_some()))
            .set("reselects_enqueued", Json::from(enqueued));
        Ok(o)
    }

    /// Answer `GET /v1/explain?key=<16 hex>`: the full search trajectory
    /// behind one cached recommendation (every probed δ with its UWT,
    /// search phase, warm/cold π start and solve iterations — DESIGN.md
    /// §15). Peeks only: explain must not perturb the cache's LRU order
    /// or its hit/miss counters. `None` when the key is not cached
    /// (evicted or never selected) — the server answers 404.
    pub fn explain_key(&self, key: u64) -> Option<Json> {
        let entry = self.cache.peek(key)?;
        Some(protocol::explain_response(
            entry.key,
            &entry.result,
            &entry.trace,
            entry.lambda,
            entry.theta,
            entry.stale,
            None,
        ))
    }

    /// Answer `GET /v1/explain?track=<id>`: one explain payload per
    /// registered spec of the track (in registration order), wrapped in
    /// a `{"track", "count", "results"}` envelope. Specs whose entries
    /// were evicted are skipped — `count` reports what survives. `None`
    /// when the track does not exist.
    pub fn explain_track(&self, track_id: &str) -> Option<Json> {
        let handle = self.track_handle(track_id)?;
        let keys: Vec<u64> = {
            let track = handle.lock().unwrap();
            track.specs.iter().map(|s| s.key).collect()
        };
        let mut results = Vec::new();
        for key in keys {
            if let Some(entry) = self.cache.peek(key) {
                results.push(protocol::explain_response(
                    entry.key,
                    &entry.result,
                    &entry.trace,
                    entry.lambda,
                    entry.theta,
                    entry.stale,
                    Some(track_id),
                ));
            }
        }
        let mut o = Json::obj();
        o.set("ok", Json::from(true))
            .set("track", Json::from(track_id))
            .set("count", Json::from(results.len()))
            .set("results", Json::Arr(results));
        Some(o)
    }

    /// One `model` probe (diagnostics; not cached).
    pub fn model(&self, req: &ModelRequest) -> Result<Json> {
        self.models.inc();
        let inputs = ModelInputs::new(req.system, &req.app, &req.policy)?;
        let builder = SharedBuilder::native(inputs, &BuildOptions::default());
        let probe = builder.probe(req.interval)?;
        let kept = probe.keep.iter().filter(|&&k| k).count();
        let mut o = Json::obj();
        o.set("ok", Json::from(true))
            .set("interval", Json::from(probe.interval))
            .set("uwt", Json::from(probe.uwt))
            .set("availability", Json::from(probe.breakdown.availability))
            .set("states", Json::from(kept))
            .set("full_states", Json::from(builder.n_states()))
            .set("eliminated", Json::from(probe.eliminated))
            .set("solve_iters", Json::from(probe.solve_iters));
        Ok(o)
    }

    /// Pop and execute one background re-selection; `false` when the
    /// queue is empty. The server's background thread loops on this;
    /// tests drive it directly.
    pub fn run_bg_once(&self) -> bool {
        let job = self.bg.lock().unwrap().pop_front();
        let Some(job) = job else {
            return false;
        };
        match self.reselect(&job) {
            Ok(()) => {
                self.bg_completed.inc();
            }
            Err(e) => {
                self.bg_errors.inc();
                let err = Json::from(format!("{e:#}"));
                let fields = [("track", Json::from(job.track.as_str())), ("error", err)];
                olog::warn("advisor", "background re-select failed", &fields);
                // Unblock the spec AND restore its drift reference: the
                // enqueue advanced rates_used to the re-fitted rates, so
                // without the rollback the next ingest would measure
                // ~zero drift and never retry, leaving the entry stale
                // forever.
                if let Some(handle) = self.track_handle(&job.track) {
                    let mut track = handle.lock().unwrap();
                    for spec in &mut track.specs {
                        if spec.key == job.old_key {
                            spec.pending = false;
                            spec.rates_used = job.prev_rates;
                        }
                    }
                }
            }
        }
        true
    }

    fn reselect(&self, job: &BgJob) -> Result<()> {
        // Documented exception to the api::SelectBatch front door
        // (DESIGN.md §11): the refresh must seed π from the pre-drift
        // recommendation, a warm-start the batch facade does not expose.
        let builder = Arc::new(SharedBuilder::native(job.inputs.clone(), &job.cfg.build));
        if let Some(pi) = &job.seed {
            builder.seed_pi(pi.clone());
        }
        let (result, trace) = select_interval_shared_traced(&builder, &job.cfg)?;
        let new_key = canonical_key(&job.inputs, &job.cfg);
        let bytes = entry_bytes(&builder, result.probes.len());
        self.cache.insert(CacheEntry {
            key: new_key,
            builder,
            result,
            trace: Arc::new(trace),
            lambda: job.inputs.system.lambda,
            theta: job.inputs.system.theta,
            bytes,
            stale: false,
        });
        if new_key != job.old_key {
            self.cache.remove(job.old_key);
        }
        if let Some(handle) = self.track_handle(&job.track) {
            let mut track = handle.lock().unwrap();
            track.reselects += 1;
            let mut refreshed: Vec<SpecRecord> = Vec::new();
            for spec in &mut track.specs {
                if spec.key == job.old_key {
                    spec.key = new_key;
                    spec.inputs = job.inputs.clone();
                    spec.pending = false;
                    refreshed.push(SpecRecord {
                        identity: Self::spec_identity(&spec.inputs, &spec.cfg),
                        key: spec.key,
                        rates_used: spec.rates_used,
                        refresh: true,
                        inputs: spec.inputs.clone(),
                        cfg: spec.cfg,
                    });
                }
            }
            for rec in refreshed {
                if let Err(e) = track.record_spec(rec) {
                    let err = Json::from(format!("{e:#}"));
                    let fields = [("track", Json::from(job.track.as_str())), ("error", err)];
                    olog::error("advisor", "refreshed recommendation not persisted", &fields);
                }
            }
        }
        Ok(())
    }

    /// Snapshot and compact every persisted track — the shutdown path
    /// (and callable any time; compaction is crash-safe). Returns the
    /// number of tracks compacted.
    pub fn persist_all(&self) -> Result<usize> {
        if self.store.is_none() {
            return Ok(0);
        }
        let handles: Vec<TrackHandle> = {
            let map = self.tracks.lock().unwrap();
            map.values().map(Arc::clone).collect()
        };
        let mut compacted = 0usize;
        for handle in handles {
            let mut track = handle.lock().unwrap();
            if track.store.is_none() {
                continue;
            }
            let state = state_of_track(&track);
            if let Some(store) = track.store.as_mut() {
                store.compact(&state)?;
                compacted += 1;
                self.compactions.inc();
            }
        }
        Ok(compacted)
    }

    /// Background-compaction sweep: every few seconds, roll any track
    /// whose WAL outgrew the store's threshold. Cheap when nothing needs
    /// doing; called from the server's background thread between jobs.
    pub fn maybe_compact(&self) {
        let Some(st) = &self.store else {
            return;
        };
        {
            let mut last = self.last_compact_check.lock().unwrap();
            if last.elapsed() < Duration::from_secs(5) {
                return;
            }
            *last = Instant::now();
        }
        let threshold = st.compact_wal_bytes();
        let handles: Vec<(String, TrackHandle)> = {
            let map = self.tracks.lock().unwrap();
            map.iter().map(|(k, h)| (k.clone(), Arc::clone(h))).collect()
        };
        for (id, handle) in handles {
            let mut track = handle.lock().unwrap();
            let needs = track.store.as_ref().is_some_and(|s| s.wal_bytes() > threshold);
            if !needs {
                continue;
            }
            let state = state_of_track(&track);
            let Some(store) = track.store.as_mut() else {
                continue;
            };
            match store.compact(&state) {
                Ok(()) => {
                    self.compactions.inc();
                }
                Err(e) => {
                    let err = Json::from(format!("{e:#}"));
                    let fields = [("track", Json::from(id.as_str())), ("error", err)];
                    olog::error("advisor", "compaction failed", &fields);
                }
            }
        }
    }

    /// Queued (not yet executed) background jobs.
    pub fn bg_pending(&self) -> usize {
        self.bg.lock().unwrap().len()
    }

    /// Block until a background job is queued or `timeout` elapses.
    pub fn bg_wait(&self, timeout: Duration) {
        let guard = self.bg.lock().unwrap();
        if guard.is_empty() {
            // Condvar::wait_timeout only errs on a poisoned mutex, which the
            // lock() above would already have propagated as a panic.
            let _unused = self.bg_cv.wait_timeout(guard, timeout).unwrap();
        }
    }

    /// The `status` report.
    pub fn status(&self) -> Json {
        let cs = self.cache.stats();
        let mut cache = Json::obj();
        cache
            .set("entries", Json::from(cs.entries))
            .set("bytes", Json::from(cs.bytes))
            .set("budget_bytes", Json::from(cs.budget_bytes))
            .set("hits", Json::from(cs.hits))
            .set("misses", Json::from(cs.misses))
            .set("insertions", Json::from(cs.insertions))
            .set("evictions", Json::from(cs.evictions));

        let mut requests = Json::obj();
        requests
            .set("select", Json::from(self.selects.get()))
            .set("select_batch", Json::from(self.select_batches.get()))
            .set("ingest", Json::from(self.ingests.get()))
            .set("model", Json::from(self.models.get()));

        let mut background = Json::obj();
        background
            .set("pending", Json::from(self.bg_pending()))
            .set("completed", Json::from(self.bg_completed.get()))
            .set("errors", Json::from(self.bg_errors.get()));

        // Snapshot the handles under the map lock, then visit each track
        // under its own lock.
        let handles: Vec<(String, TrackHandle)> = {
            let map = self.tracks.lock().unwrap();
            let mut v: Vec<(String, TrackHandle)> =
                map.iter().map(|(k, h)| (k.clone(), Arc::clone(h))).collect();
            v.sort_by(|a, b| a.0.cmp(&b.0));
            v
        };
        let mut tracks_json = Json::obj();
        for (id, handle) in handles {
            let track = handle.lock().unwrap();
            let mut tj = Json::obj();
            tj.set("n_procs", Json::from(track.n_procs))
                .set("events", Json::from(track.tail.n_events()))
                .set("accepted", Json::from(track.accepted))
                .set("merged", Json::from(track.merged))
                .set("evicted", Json::from(track.evicted))
                .set("reselects", Json::from(track.reselects))
                .set("persisted", Json::from(track.store.is_some()));
            if let Some(store) = &track.store {
                tj.set("wal_bytes", Json::from(store.wal_bytes()));
            }
            if let Some((l, t)) = track.rates {
                tj.set("lambda", Json::from(l)).set("theta", Json::from(t));
            }
            let mut recs = Vec::new();
            for spec in &track.specs {
                let mut rj = Json::obj();
                rj.set("key", Json::from(key_hex(spec.key)))
                    .set("pending", Json::from(spec.pending))
                    .set("lambda", Json::from(spec.rates_used.0))
                    .set("theta", Json::from(spec.rates_used.1));
                if let Some(entry) = self.cache.peek(spec.key) {
                    rj.set("interval", Json::from(entry.result.interval))
                        .set("uwt", Json::from(entry.result.uwt))
                        .set("stale", Json::from(entry.stale));
                }
                recs.push(rj);
            }
            tj.set("recommendations", Json::Arr(recs));
            tracks_json.set(&id, tj);
        }

        let mut store_json = Json::obj();
        store_json.set("enabled", Json::from(self.store.is_some()));
        if let Some(st) = &self.store {
            store_json
                .set("dir", Json::from(st.root().display().to_string().as_str()))
                .set("compact_wal_bytes", Json::from(st.compact_wal_bytes()))
                .set("compactions", Json::from(self.compactions.get()));
        }

        let mut o = Json::obj();
        o.set("ok", Json::from(true))
            .set("uptime_s", Json::from(self.started.elapsed().as_secs_f64()))
            .set("drift_threshold", Json::from(self.cfg.drift_threshold))
            .set("refit_window_s", Json::from(self.cfg.refit_window))
            .set("max_events", Json::from(self.cfg.max_events))
            .set("requests", requests)
            .set("cache", cache)
            .set("background", background)
            .set("store", store_json)
            .set("tracks", tracks_json);
        o
    }

    /// Refresh the process-global registry from this advisor's state —
    /// called by the server right before rendering `/metrics`. Touching
    /// every layer's handle struct here also guarantees the very first
    /// scrape already lists the server, cache, store, replication and
    /// search families. Counters mirror via `set_max` (monotone even if
    /// several advisors share the process); gauges are last-write-wins.
    pub fn publish_obs(&self) {
        let o = advisor_obs();
        server::http_obs();
        crate::store::store_obs();
        replicate::replication_obs();
        crate::search::search_obs();

        o.req_select.set_max(self.selects.get());
        o.req_select_batch.set_max(self.select_batches.get());
        o.req_ingest.set_max(self.ingests.get());
        o.req_model.set_max(self.models.get());
        o.bg_completed.set_max(self.bg_completed.get());
        o.bg_errors.set_max(self.bg_errors.get());
        o.compactions.set_max(self.compactions.get());
        o.bg_pending.set(self.bg_pending() as f64);

        let cs = self.cache.stats();
        o.cache_hits.set_max(cs.hits);
        o.cache_misses.set_max(cs.misses);
        o.cache_insertions.set_max(cs.insertions);
        o.cache_evictions.set_max(cs.evictions);
        o.cache_entries.set(cs.entries as f64);
        o.cache_bytes.set(cs.bytes as f64);
        o.cache_budget_bytes.set(cs.budget_bytes as f64);

        let handles: Vec<(String, TrackHandle)> = {
            let map = self.tracks.lock().unwrap();
            map.iter().map(|(k, h)| (k.clone(), Arc::clone(h))).collect()
        };
        let reg = obs::global();
        for (id, handle) in handles {
            let track = handle.lock().unwrap();
            let labels = [("track", id.as_str())];
            let events =
                reg.gauge_with("mckpt_track_events", "Events in the track's tail.", &labels);
            events.set(track.tail.n_events() as f64);
            if let Some((l, t)) = track.rates {
                reg.gauge_with("mckpt_track_lambda", "Fitted failure rate (1/s).", &labels)
                    .set(l);
                reg.gauge_with("mckpt_track_theta", "Fitted repair rate (1/s).", &labels)
                    .set(t);
            }
            // Worst relative drift of any served recommendation against
            // the current re-fit — the distance to the next re-select.
            let drift = track
                .rates
                .map(|fresh| {
                    track
                        .specs
                        .iter()
                        .filter(|s| !s.pending)
                        .map(|s| relative_drift(s.rates_used, fresh))
                        .fold(0.0, f64::max)
                })
                .unwrap_or(0.0);
            reg.gauge_with(
                "mckpt_track_drift",
                "Max relative rate drift of a served recommendation.",
                &labels,
            )
            .set(drift);
            if let Some(store) = &track.store {
                reg.gauge_with("mckpt_track_wal_bytes", "Track WAL size, bytes.", &labels)
                    .set(store.wal_bytes() as f64);
            }
        }
    }
}

/// Registry handles for the advisor layer, resolved once.
struct AdvisorObs {
    req_select: Arc<obs::Counter>,
    req_select_batch: Arc<obs::Counter>,
    req_ingest: Arc<obs::Counter>,
    req_model: Arc<obs::Counter>,
    bg_completed: Arc<obs::Counter>,
    bg_errors: Arc<obs::Counter>,
    compactions: Arc<obs::Counter>,
    bg_pending: Arc<obs::Gauge>,
    cache_hits: Arc<obs::Counter>,
    cache_misses: Arc<obs::Counter>,
    cache_insertions: Arc<obs::Counter>,
    cache_evictions: Arc<obs::Counter>,
    cache_entries: Arc<obs::Gauge>,
    cache_bytes: Arc<obs::Gauge>,
    cache_budget_bytes: Arc<obs::Gauge>,
    select_seconds: Arc<obs::Histogram>,
}

fn advisor_obs() -> &'static AdvisorObs {
    static OBS: OnceLock<AdvisorObs> = OnceLock::new();
    OBS.get_or_init(|| {
        let r = obs::global();
        let req = "Requests handled, by advisor endpoint.";
        let bg = "Background re-selections, by outcome.";
        AdvisorObs {
            req_select: r.counter_with("mckpt_requests_total", req, &[("endpoint", "select")]),
            req_select_batch: r.counter_with(
                "mckpt_requests_total",
                req,
                &[("endpoint", "select_batch")],
            ),
            req_ingest: r.counter_with("mckpt_requests_total", req, &[("endpoint", "ingest")]),
            req_model: r.counter_with("mckpt_requests_total", req, &[("endpoint", "model")]),
            bg_completed: r.counter_with("mckpt_bg_jobs_total", bg, &[("outcome", "completed")]),
            bg_errors: r.counter_with("mckpt_bg_jobs_total", bg, &[("outcome", "error")]),
            compactions: r.counter("mckpt_compactions_total", "Track WAL compactions."),
            bg_pending: r.gauge("mckpt_bg_pending", "Queued background re-selections."),
            cache_hits: r.counter("mckpt_cache_hits_total", "Recommendation cache hits."),
            cache_misses: r.counter("mckpt_cache_misses_total", "Recommendation cache misses."),
            cache_insertions: r
                .counter("mckpt_cache_insertions_total", "Recommendation cache insertions."),
            cache_evictions: r
                .counter("mckpt_cache_evictions_total", "Recommendation cache evictions."),
            cache_entries: r.gauge("mckpt_cache_entries", "Live recommendation cache entries."),
            cache_bytes: r.gauge("mckpt_cache_bytes", "Recommendation cache footprint, bytes."),
            cache_budget_bytes: r
                .gauge("mckpt_cache_budget_bytes", "Recommendation cache budget, bytes."),
            select_seconds: r.histogram(
                "mckpt_advisor_select_seconds",
                "Advisor select latency (cache hits and misses).",
                obs::LATENCY_BUCKETS,
            ),
        }
    })
}

/// Bytes a cache entry charges against the budget: the builder's
/// interval-independent caches plus the stored probes and bookkeeping.
fn entry_bytes(builder: &SharedBuilder, probes: usize) -> usize {
    builder.cache_bytes() + probes * std::mem::size_of::<(f64, f64)>() + 256
}

/// Rebuild a live [`Track`] from recovered durable state. Pending flags
/// are not persisted: an in-flight background re-selection died with the
/// old process, and leaving the spec non-pending lets the next ingest
/// re-detect any drift and retry.
fn track_from_state(state: TrackState) -> Result<Track> {
    let specs = state
        .specs
        .into_iter()
        .map(|r| TrackedSpec {
            key: r.key,
            inputs: r.inputs,
            cfg: r.cfg,
            rates_used: r.rates_used,
            pending: false,
        })
        .collect();
    Ok(Track {
        n_procs: state.tail.n_procs(),
        tail: state.tail,
        rates: state.rates,
        specs,
        accepted: state.accepted,
        merged: state.merged,
        reselects: state.reselects,
        evicted: state.evicted,
        store: None,
        sharded: None,
    })
}

/// Snapshot a live track as the durable state a compaction writes.
fn state_of_track(track: &Track) -> TrackState {
    TrackState {
        tail: track.tail.clone(),
        rates: track.rates,
        specs: track
            .specs
            .iter()
            .map(|s| SpecRecord {
                identity: Advisor::spec_identity(&s.inputs, &s.cfg),
                key: s.key,
                rates_used: s.rates_used,
                refresh: false,
                inputs: s.inputs.clone(),
                cfg: s.cfg,
            })
            .collect(),
        accepted: track.accepted,
        merged: track.merged,
        reselects: track.reselects,
        evicted: track.evicted,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ComputeEngine;
    use crate::search::select_interval;
    use crate::util::rng::Rng;

    fn select_req(mttf_days: f64, track: Option<&str>) -> SelectRequest {
        let mut body = format!(
            r#"{{"system": {{"n": 6, "mttf_days": {mttf_days}, "mttr_min": 40}},
                 "search": {{"refine_steps": 3}}"#
        );
        if let Some(t) = track {
            body.push_str(&format!(r#", "track": "{t}""#));
        }
        body.push('}');
        protocol::parse_select(&Json::parse(&body).unwrap()).unwrap()
    }

    fn oracle(req: &SelectRequest) -> crate::search::SearchResult {
        let inputs = ModelInputs::new(req.system, &req.app, &req.policy).unwrap();
        select_interval(&inputs, &ComputeEngine::native(), &req.cfg).unwrap()
    }

    fn volatile_ingest(track: &str, seed: u64) -> IngestRequest {
        // A 200-day MTTF-1-day trace on 6 processors: ~8x the failure
        // rate of the select_req(8.0, ..) requests.
        let mut rng = Rng::new(seed);
        let trace = crate::traces::synth::generate(
            &crate::traces::synth::SynthSpec::exponential(
                6,
                1.0 / 86_400.0,
                1.0 / 2_400.0,
                200.0 * 86_400.0,
            ),
            &mut rng,
        );
        let mut events = Vec::new();
        for p in 0..6 {
            for &(f, r) in trace.outages(p) {
                events.push(format!(r#"{{"proc": {p}, "fail": {f}, "repair": {r}}}"#));
            }
        }
        let body = format!(
            r#"{{"track": "{track}", "n_procs": 6, "events": [{}]}}"#,
            events.join(",")
        );
        protocol::parse_ingest(&Json::parse(&body).unwrap()).unwrap()
    }

    #[test]
    fn select_matches_offline_oracle_and_caches() {
        let advisor = Advisor::new(AdvisorConfig::default());
        let req = select_req(2.0, None);
        let want = oracle(&req);
        let first = advisor.select(&req).unwrap();
        assert_eq!(first.get("cached").unwrap().as_bool(), Some(false));
        assert_eq!(first.get("interval").unwrap().as_f64(), Some(want.interval));
        assert_eq!(first.get("uwt").unwrap().as_f64(), Some(want.uwt));
        let again = advisor.select(&req).unwrap();
        assert_eq!(again.get("cached").unwrap().as_bool(), Some(true));
        assert_eq!(again.get("interval").unwrap().as_f64(), Some(want.interval));
        let stats = advisor.cache.stats();
        assert_eq!((stats.hits, stats.misses, stats.entries), (1, 1, 1));
        // A different system is a different key.
        let other = advisor.select(&select_req(8.0, None)).unwrap();
        assert_eq!(other.get("cached").unwrap().as_bool(), Some(false));
        assert_ne!(
            other.get("key").unwrap().as_str(),
            first.get("key").unwrap().as_str()
        );
    }

    #[test]
    fn explain_serves_the_cached_search_trajectory() {
        let advisor = Advisor::new(AdvisorConfig::default());
        assert!(advisor.explain_key(0xdead).is_none(), "unknown key must 404");
        assert!(advisor.explain_track("nope").is_none(), "unknown track must 404");
        let req = select_req(2.0, Some("c1"));
        let resp = advisor.select(&req).unwrap();
        let key = u64::from_str_radix(resp.get("key").unwrap().as_str().unwrap(), 16).unwrap();
        let ex = advisor.explain_key(key).unwrap();
        assert_eq!(ex.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(ex.get("stale").unwrap().as_bool(), Some(false));
        assert_eq!(
            ex.get("interval").unwrap().as_f64(),
            resp.get("interval").unwrap().as_f64()
        );
        // One trace probe per evaluation; re-sorted by interval they are
        // exactly the result's probed (interval, UWT) pairs.
        let probes = ex.get("probes").unwrap().as_arr().unwrap();
        assert_eq!(
            probes.len() as f64,
            resp.get("evaluations").unwrap().as_f64().unwrap()
        );
        let mut pairs: Vec<(f64, f64)> = probes
            .iter()
            .map(|p| {
                (
                    p.get("interval").unwrap().as_f64().unwrap(),
                    p.get("uwt").unwrap().as_f64().unwrap(),
                )
            })
            .collect();
        pairs.sort_by(|a, b| a.0.total_cmp(&b.0));
        let want: Vec<(f64, f64)> = resp
            .get("probes")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|p| {
                let p = p.as_arr().unwrap();
                (p[0].as_f64().unwrap(), p[1].as_f64().unwrap())
            })
            .collect();
        assert_eq!(pairs, want, "trace probes must mirror the result's probe set");
        // The first probe is the cold doubling probe at i_min.
        assert_eq!(probes[0].get("phase").unwrap().as_str(), Some("doubling"));
        assert_eq!(probes[0].get("warm").unwrap().as_bool(), Some(false));
        // The track view wraps the same payload per registered spec.
        let tv = advisor.explain_track("c1").unwrap();
        assert_eq!(tv.get("count").unwrap().as_f64(), Some(1.0));
        let r0 = &tv.get("results").unwrap().as_arr().unwrap()[0];
        assert_eq!(r0.get("key").unwrap().as_str(), resp.get("key").unwrap().as_str());
        assert_eq!(r0.get("track").unwrap().as_str(), Some("c1"));
        assert_eq!(r0.get("interval").unwrap().as_f64(), resp.get("interval").unwrap().as_f64());
    }

    #[test]
    fn drift_triggers_background_reselect_with_updated_rates() {
        let advisor = Advisor::new(AdvisorConfig {
            drift_threshold: 0.5,
            refit_window: 400.0 * 86_400.0,
            min_refit_failures: 8,
            ..Default::default()
        });
        let req = select_req(8.0, Some("c1"));
        let first = advisor.select(&req).unwrap();
        let old_interval = first.get("interval").unwrap().as_f64().unwrap();

        let resp = advisor.ingest(&volatile_ingest("c1", 11)).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("reselects_enqueued").unwrap().as_f64(), Some(1.0));
        let lam_hat = resp.get("lambda").unwrap().as_f64().unwrap();
        let theta_hat = resp.get("theta").unwrap().as_f64().unwrap();
        assert!(
            (lam_hat * 86_400.0 - 1.0).abs() < 0.3,
            "re-fit λ̂ should be near 1/day, got 1/{:.2}d",
            1.0 / (lam_hat * 86_400.0)
        );

        // While pending, the stale entry still serves (flagged, cached).
        assert_eq!(advisor.bg_pending(), 1);
        let stale = advisor.select(&req).unwrap();
        assert_eq!(stale.get("cached").unwrap().as_bool(), Some(true));
        assert_eq!(stale.get("stale").unwrap().as_bool(), Some(true));

        // Drain the background queue and check the refreshed entry
        // against the offline oracle at the re-fitted rates.
        assert!(advisor.run_bg_once());
        assert!(!advisor.run_bg_once());
        let status = advisor.status();
        let track = status.path("tracks.c1").unwrap();
        assert_eq!(track.path("reselects").unwrap().as_f64(), Some(1.0));
        let rec = &track.path("recommendations").unwrap().as_arr().unwrap()[0];
        assert_eq!(rec.get("pending").unwrap().as_bool(), Some(false));
        assert_eq!(rec.get("stale").unwrap().as_bool(), Some(false));
        let new_interval = rec.get("interval").unwrap().as_f64().unwrap();
        assert!(
            new_interval < old_interval,
            "8x more failures must shorten the interval: {new_interval} !< {old_interval}"
        );
        let mut want_req = select_req(8.0, None);
        want_req.system.lambda = lam_hat;
        want_req.system.theta = theta_hat;
        let want = oracle(&want_req);
        let rel = (new_interval - want.interval).abs() / want.interval;
        assert!(rel < 1e-9, "reselect diverged from oracle: {new_interval} vs {}", want.interval);
        let got_uwt = rec.get("uwt").unwrap().as_f64().unwrap();
        let rel_u = (got_uwt - want.uwt).abs() / want.uwt;
        assert!(rel_u < 1e-9, "UWT diverged: {got_uwt} vs {}", want.uwt);

        // A fresh tracked select now uses the re-fitted rates: cache hit
        // on the new key.
        let after = advisor.select(&req).unwrap();
        assert_eq!(after.get("cached").unwrap().as_bool(), Some(true));
        assert_eq!(after.get("interval").unwrap().as_f64(), Some(new_interval));
    }

    #[test]
    fn select_batch_mixes_cached_cold_duplicate_and_error_items() {
        let advisor = Advisor::new(AdvisorConfig::default());
        let warm = advisor.select(&select_req(2.0, None)).unwrap();
        let mut bad = select_req(8.0, None);
        bad.cfg.i_min = -1.0;
        let reqs =
            vec![select_req(2.0, None), select_req(8.0, None), select_req(8.0, None), bad];
        let resp = advisor.select_batch(&reqs);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("count").unwrap().as_f64(), Some(4.0));
        let results = resp.get("results").unwrap().as_arr().unwrap();
        // Item 0: O(1) hit on the entry the singleton select warmed.
        assert_eq!(results[0].get("cached").unwrap().as_bool(), Some(true));
        assert_eq!(
            results[0].get("interval").unwrap().as_f64(),
            warm.get("interval").unwrap().as_f64()
        );
        // Items 1/2: identical cold specs — answered in order, pinned to
        // the offline oracle, deduped into one build and one cache entry.
        let want = oracle(&select_req(8.0, None));
        for r in &results[1..3] {
            assert_eq!(r.get("cached").unwrap().as_bool(), Some(false));
            assert_eq!(r.get("interval").unwrap().as_f64(), Some(want.interval));
            assert_eq!(r.get("uwt").unwrap().as_f64(), Some(want.uwt));
        }
        assert_eq!(
            results[1].get("key").unwrap().as_str(),
            results[2].get("key").unwrap().as_str()
        );
        // Item 3: a per-item error naming its index; siblings unaffected.
        assert_eq!(results[3].get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(results[3].get("index").unwrap().as_f64(), Some(3.0));
        assert!(results[3].get("error").unwrap().as_str().unwrap().contains("i_min"));
        let stats = advisor.cache.stats();
        assert_eq!(stats.entries, 2, "duplicate specs must share one cache entry");
        assert_eq!(stats.insertions, 2);
        // The batch's cold build now serves repeats from the cache.
        let again = advisor.select_batch(&reqs[1..2]);
        let again = &again.get("results").unwrap().as_arr().unwrap()[0];
        assert_eq!(again.get("cached").unwrap().as_bool(), Some(true));
        assert_eq!(again.get("interval").unwrap().as_f64(), Some(want.interval));
    }

    #[test]
    fn small_drift_keeps_serving_the_cached_entry() {
        let advisor = Advisor::new(AdvisorConfig {
            drift_threshold: 1e9, // nothing drifts past this
            refit_window: 400.0 * 86_400.0,
            min_refit_failures: 2,
            ..Default::default()
        });
        let req = select_req(2.0, Some("c1"));
        let first = advisor.select(&req).unwrap();
        let body = r#"{"track": "c1", "n_procs": 6, "events": [
            {"proc": 0, "fail": 1000, "repair": 3000},
            {"proc": 1, "fail": 90000, "repair": 91000},
            {"proc": 2, "fail": 200000, "repair": 201000}]}"#;
        let ing = protocol::parse_ingest(&Json::parse(body).unwrap()).unwrap();
        let resp = advisor.ingest(&ing).unwrap();
        assert_eq!(resp.get("reselects_enqueued").unwrap().as_f64(), Some(0.0));
        assert_eq!(advisor.bg_pending(), 0);
        // Sub-threshold jitter must NOT re-key the request: the select
        // after the re-fit is still an O(1) hit on the original entry.
        let after = advisor.select(&req).unwrap();
        assert_eq!(after.get("cached").unwrap().as_bool(), Some(true));
        assert_eq!(after.get("stale").unwrap().as_bool(), Some(false));
        assert_eq!(after.get("key").unwrap().as_str(), first.get("key").unwrap().as_str());
        assert_eq!(
            after.get("interval").unwrap().as_f64(),
            first.get("interval").unwrap().as_f64()
        );
        // And the drift reference still describes the served entry (the
        // rates it was built with), so slow creep cannot be absorbed by
        // a silently advancing baseline.
        let status = advisor.status();
        let rec = &status.path("tracks.c1.recommendations").unwrap().as_arr().unwrap()[0];
        assert_eq!(
            rec.get("lambda").unwrap().as_f64(),
            first.get("lambda").unwrap().as_f64()
        );
    }

    #[test]
    fn failed_reselect_restores_drift_reference() {
        // A background job that fails must roll rates_used back so the
        // next ingest re-detects the drift and retries (otherwise the
        // entry stays stale forever).
        let advisor = Advisor::new(AdvisorConfig {
            drift_threshold: 0.5,
            refit_window: 400.0 * 86_400.0,
            min_refit_failures: 8,
            ..Default::default()
        });
        let req = select_req(8.0, Some("c1"));
        advisor.select(&req).unwrap();
        advisor.ingest(&volatile_ingest("c1", 31)).unwrap();
        assert_eq!(advisor.bg_pending(), 1);
        // Sabotage the queued job so reselect() errors.
        {
            let mut bg = advisor.bg.lock().unwrap();
            bg.front_mut().unwrap().cfg.i_min = -1.0; // fails validation
        }
        assert!(advisor.run_bg_once());
        assert_eq!(advisor.bg_errors.get(), 1);
        // The spec is unblocked and its drift reference restored...
        {
            let handle = advisor.track_handle("c1").unwrap();
            let track = handle.lock().unwrap();
            let spec = &track.specs[0];
            assert!(!spec.pending);
            let fresh = track.rates.unwrap();
            assert!(
                relative_drift(spec.rates_used, fresh) > 0.5,
                "rollback lost: drift reference equals the re-fit"
            );
        }
        // ...so the next ingest re-detects the drift and re-enqueues a
        // (healthy) job, which completes.
        let more = protocol::parse_ingest(
            &Json::parse(
                r#"{"track": "c1", "events": [
                    {"proc": 0, "fail": 17280500, "repair": 17282900},
                    {"proc": 1, "fail": 17290000, "repair": 17292400}]}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let resp = advisor.ingest(&more).unwrap();
        assert_eq!(resp.get("reselects_enqueued").unwrap().as_f64(), Some(1.0));
        assert!(advisor.run_bg_once());
        assert_eq!(advisor.bg_completed.get(), 1);
    }

    #[test]
    fn retention_cap_applies_on_ingest() {
        let advisor = Advisor::new(AdvisorConfig {
            max_events: 6,
            retention_window: 86_400.0,
            ..Default::default()
        });
        // 5 outages = 10 events across days 0..5: the cap must trim the
        // oldest days down to <= 6 events (3 outages).
        let body = r#"{"track": "t", "n_procs": 4, "events": [
            {"proc": 0, "fail": 1000, "repair": 2000},
            {"proc": 1, "fail": 90000, "repair": 91000},
            {"proc": 2, "fail": 180000, "repair": 181000},
            {"proc": 3, "fail": 270000, "repair": 271000},
            {"proc": 0, "fail": 360000, "repair": 361000}]}"#;
        let ing = protocol::parse_ingest(&Json::parse(body).unwrap()).unwrap();
        let resp = advisor.ingest(&ing).unwrap();
        assert_eq!(resp.get("accepted").unwrap().as_f64(), Some(5.0));
        assert_eq!(resp.get("evicted").unwrap().as_f64(), Some(4.0));
        assert_eq!(resp.get("events_total").unwrap().as_f64(), Some(6.0));
        let status = advisor.status();
        assert_eq!(status.path("tracks.t.evicted").unwrap().as_f64(), Some(4.0));
        assert_eq!(status.path("tracks.t.persisted").unwrap().as_bool(), Some(false));
        assert_eq!(status.path("store.enabled").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn ingest_track_bookkeeping() {
        let advisor = Advisor::new(AdvisorConfig::default());
        // First ingest without n_procs fails; with it, creates the track.
        let no_n = protocol::parse_ingest(
            &Json::parse(r#"{"track": "t", "events": []}"#).unwrap(),
        )
        .unwrap();
        assert!(advisor.ingest(&no_n).is_err());
        let mk = protocol::parse_ingest(
            &Json::parse(r#"{"track": "t", "n_procs": 4, "events": []}"#).unwrap(),
        )
        .unwrap();
        advisor.ingest(&mk).unwrap();
        // Mismatched n_procs on an existing track is rejected.
        let bad = protocol::parse_ingest(
            &Json::parse(r#"{"track": "t", "n_procs": 5, "events": []}"#).unwrap(),
        )
        .unwrap();
        assert!(advisor.ingest(&bad).is_err());
        let status = advisor.status();
        assert_eq!(status.path("tracks.t.n_procs").unwrap().as_f64(), Some(4.0));
    }
}
