//! The advisor's wire schema: strict JSON request parsing and response
//! building over [`crate::util::json::Json`] (the repo's hand-rolled JSON
//! — no serde in the vendor set, matching `util::cli`'s approach to
//! argument parsing).
//!
//! Every request field is validated here with a clear error — the daemon
//! receives these values from untrusted clients, so nothing reaches the
//! model layer unchecked. Floats round-trip exactly: the serializer emits
//! shortest-roundtrip decimals, which is what lets the end-to-end tests
//! (and the CI smoke job) compare daemon recommendations against the
//! offline oracle bit for bit.
//!
//! ## `POST /v1/select`
//!
//! ```json
//! {
//!   "system": "system-1/128",
//!   "app": "qr",
//!   "policy": "greedy",
//!   "search": {"i_min": 300, "refine_steps": 6},
//!   "track": "cluster-a"
//! }
//! ```
//!
//! `system` is a paper Table II name or `{"n": 128, "lambda": ...,
//! "theta": ...}` (or `mttf_days`/`mttr_min` in place of the rates);
//! `app` is `qr`/`cg`/`md` or explicit cost vectors `{"name", "work",
//! "ckpt", "rec_same", "rec_span"}`; `policy` is `greedy`, `pb` or
//! `{"rp": [...]}`. All except `system` are optional. `track` opts the
//! request into ingest-driven refresh (see [`crate::advisor::ingest`]).
//!
//! ## `POST /v1/select_batch`
//!
//! ```json
//! {"items": [
//!   {"system": "system-1/128", "app": "qr"},
//!   {"system": "condor/64", "app": "md", "track": "cluster-b"}
//! ]}
//! ```
//!
//! Each item carries the full `select` schema (per-item `track`
//! included). A malformed item fails the whole request with `400` naming
//! the offending index (`items[3]: ...`); a *runtime* per-item failure
//! after parsing becomes a per-item `{"ok": false, "index": ...,
//! "error": ...}` in `results` without poisoning its siblings. `results`
//! is positional: `results[i]` answers `items[i]`.
//!
//! ## `POST /v1/ingest`
//!
//! ```json
//! {"track": "cluster-a", "n_procs": 128,
//!  "events": [{"proc": 3, "fail": 120.5, "repair": 2520.0}]}
//! ```
//!
//! This module parses untrusted bytes, so it is under srclint's
//! whole-file no-panic-paths rule: typed errors only, no unwraps, no
//! unguarded indexing (DESIGN.md §16).
#![warn(clippy::unwrap_used, clippy::expect_used, clippy::panic)]

use anyhow::{anyhow, bail, Context, Result};

use super::ingest::IngestEvent;
use crate::apps::AppProfile;
use crate::config::{paper_system, SystemParams};
use crate::policies::ReschedulingPolicy;
use crate::search::{SearchConfig, SearchResult, SearchTrace};
use crate::util::json::Json;

/// A parsed, validated `select` request (rates not yet track-adjusted —
/// the advisor substitutes a track's re-fitted rates before keying).
pub struct SelectRequest {
    pub system: SystemParams,
    pub app: AppProfile,
    pub policy: ReschedulingPolicy,
    pub cfg: SearchConfig,
    pub track: Option<String>,
}

/// A parsed `model` request (one interval probe, diagnostics endpoint).
pub struct ModelRequest {
    pub system: SystemParams,
    pub app: AppProfile,
    pub policy: ReschedulingPolicy,
    pub interval: f64,
}

/// A parsed `ingest` batch.
pub struct IngestRequest {
    pub track: String,
    /// Required the first time a track is seen; checked against the
    /// existing track afterwards (when present).
    pub n_procs: Option<usize>,
    pub events: Vec<IngestEvent>,
}

fn get_f64(j: &Json, key: &str) -> Result<Option<f64>> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v
            .as_f64()
            .map(Some)
            .ok_or_else(|| anyhow!("'{key}' must be a number")),
    }
}

fn get_usize(j: &Json, key: &str) -> Result<Option<usize>> {
    match get_f64(j, key)? {
        None => Ok(None),
        Some(x) => {
            if x < 0.0 || x.fract() != 0.0 || x > u32::MAX as f64 {
                bail!("'{key}' must be a non-negative integer, got {x}");
            }
            Ok(Some(x as usize))
        }
    }
}

fn parse_system(j: &Json) -> Result<SystemParams> {
    let sys = match j {
        Json::Str(name) => paper_system(name)
            .ok_or_else(|| anyhow!("unknown system '{name}'; see config::TABLE2_SYSTEMS"))?,
        Json::Obj(_) => {
            let n = get_usize(j, "n")?.context("system.n missing")?;
            match (get_f64(j, "lambda")?, get_f64(j, "theta")?) {
                (Some(lambda), Some(theta)) => SystemParams::new(n, lambda, theta),
                (None, None) => {
                    let mttf = get_f64(j, "mttf_days")?
                        .context("system needs lambda/theta or mttf_days/mttr_min")?;
                    let mttr = get_f64(j, "mttr_min")?.context("system.mttr_min missing")?;
                    SystemParams::from_mttf_mttr(n, mttf, mttr)
                }
                _ => bail!("system needs both lambda and theta (or mttf_days/mttr_min)"),
            }
        }
        _ => bail!("'system' must be a paper system name or an object"),
    };
    sys.validate()?;
    Ok(sys)
}

fn f64_vec(j: &Json, key: &str) -> Result<Vec<f64>> {
    let arr = j
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("'{key}' must be an array of numbers"))?;
    arr.iter()
        .map(|v| v.as_f64().ok_or_else(|| anyhow!("'{key}' must hold only numbers")))
        .collect()
}

fn parse_app(j: Option<&Json>, n: usize) -> Result<AppProfile> {
    match j {
        None => Ok(AppProfile::qr(n)),
        Some(Json::Str(name)) => match name.as_str() {
            "qr" => Ok(AppProfile::qr(n)),
            "cg" => Ok(AppProfile::cg(n)),
            "md" => Ok(AppProfile::md(n)),
            other => bail!("unknown app '{other}' (qr|cg|md or explicit vectors)"),
        },
        Some(obj @ Json::Obj(_)) => {
            let name = obj.get("name").and_then(Json::as_str).unwrap_or("custom");
            let work = f64_vec(obj, "work")?;
            let ckpt = f64_vec(obj, "ckpt")?;
            let rec_same = get_f64(obj, "rec_same")?.context("app.rec_same missing")?;
            let rec_span = get_f64(obj, "rec_span")?.unwrap_or(0.0);
            let app = AppProfile::from_vectors(name, work, ckpt, rec_same, rec_span)?;
            if app.n() < n {
                bail!("app vectors cover {} processors, system has {n}", app.n());
            }
            Ok(app)
        }
        Some(_) => bail!("'app' must be a name or an object with cost vectors"),
    }
}

fn parse_policy(j: Option<&Json>, app: &AppProfile, n: usize) -> Result<ReschedulingPolicy> {
    match j {
        None => Ok(ReschedulingPolicy::greedy(n)),
        Some(Json::Str(name)) => match name.as_str() {
            "greedy" => Ok(ReschedulingPolicy::greedy(n)),
            "pb" => {
                let work = app.work_vector();
                let work = work.get(..n).ok_or_else(|| {
                    anyhow!("app vectors cover {} processors, system has {n}", work.len())
                })?;
                ReschedulingPolicy::performance_based(work)
            }
            other => bail!("unknown policy '{other}' (greedy|pb or {{\"rp\": [...]}})"),
        },
        Some(obj @ Json::Obj(_)) => {
            let rp = f64_vec(obj, "rp")?;
            let rp: Vec<usize> = rp
                .into_iter()
                .map(|x| {
                    if x >= 1.0 && x.fract() == 0.0 {
                        Ok(x as usize)
                    } else {
                        Err(anyhow!("rp entries must be positive integers, got {x}"))
                    }
                })
                .collect::<Result<_>>()?;
            if rp.len() != n {
                bail!("rp has {} entries, system has {n}", rp.len());
            }
            ReschedulingPolicy::from_vector(rp)
        }
        Some(_) => bail!("'policy' must be a name or {{\"rp\": [...]}}"),
    }
}

fn parse_search(j: Option<&Json>) -> Result<SearchConfig> {
    let mut cfg = SearchConfig::default();
    if let Some(s) = j {
        if !matches!(s, Json::Obj(_)) {
            bail!("'search' must be an object");
        }
        if let Some(x) = get_f64(s, "i_min")? {
            cfg.i_min = x;
        }
        if let Some(x) = get_f64(s, "i_max")? {
            cfg.i_max = x;
        }
        if let Some(x) = get_usize(s, "refine_steps")? {
            cfg.refine_steps = x;
        }
        if let Some(x) = get_f64(s, "band")? {
            cfg.band = x;
        }
        if let Some(x) = get_f64(s, "thres")? {
            cfg.build.thres = if x > 0.0 { Some(x) } else { None };
        }
        if let Some(x) = s.get("exact_probes").and_then(Json::as_bool) {
            cfg.build.exact_probes = x;
        }
    }
    cfg.validate()?;
    Ok(cfg)
}

pub fn parse_select(j: &Json) -> Result<SelectRequest> {
    let system = parse_system(j.get("system").context("'system' is required")?)?;
    let app = parse_app(j.get("app"), system.n)?;
    let policy = parse_policy(j.get("policy"), &app, system.n)?;
    let cfg = parse_search(j.get("search"))?;
    let track = match j.get("track") {
        None | Some(Json::Null) => None,
        Some(Json::Str(s)) if !s.is_empty() => Some(s.clone()),
        Some(_) => bail!("'track' must be a non-empty string"),
    };
    Ok(SelectRequest { system, app, policy, cfg, track })
}

/// Items accepted per `select_batch` request — past this a client should
/// split its batch (the body-size cap would bite soon anyway).
pub const MAX_BATCH_ITEMS: usize = 1024;

/// Parse a `select_batch` body: a non-empty `items` array of `select`
/// request objects. Any malformed item fails the whole parse with its
/// index — the caller answers `400`; per-item *runtime* errors are the
/// advisor's job, not the parser's.
pub fn parse_select_batch(j: &Json) -> Result<Vec<SelectRequest>> {
    let arr = j
        .get("items")
        .and_then(Json::as_arr)
        .context("'items' (array of select requests) is required")?;
    if arr.is_empty() {
        bail!("'items' must not be empty");
    }
    if arr.len() > MAX_BATCH_ITEMS {
        bail!("batch of {} items exceeds the {MAX_BATCH_ITEMS}-item cap", arr.len());
    }
    arr.iter()
        .enumerate()
        .map(|(i, item)| parse_select(item).with_context(|| format!("items[{i}]")))
        .collect()
}

pub fn parse_model(j: &Json) -> Result<ModelRequest> {
    let system = parse_system(j.get("system").context("'system' is required")?)?;
    let app = parse_app(j.get("app"), system.n)?;
    let policy = parse_policy(j.get("policy"), &app, system.n)?;
    let interval = get_f64(j, "interval")?.unwrap_or(3_600.0);
    if !(interval > 0.0) || !interval.is_finite() {
        bail!("'interval' must be positive and finite, got {interval}");
    }
    Ok(ModelRequest { system, app, policy, interval })
}

pub fn parse_ingest(j: &Json) -> Result<IngestRequest> {
    let track = j
        .get("track")
        .and_then(Json::as_str)
        .filter(|s| !s.is_empty())
        .context("'track' (non-empty string) is required")?
        .to_string();
    let n_procs = get_usize(j, "n_procs")?;
    if n_procs == Some(0) {
        bail!("'n_procs' must be positive");
    }
    let arr = j
        .get("events")
        .and_then(Json::as_arr)
        .context("'events' (array) is required")?;
    let mut events = Vec::with_capacity(arr.len());
    for (i, e) in arr.iter().enumerate() {
        let ctx = || format!("events[{i}]");
        let proc = get_usize(e, "proc").with_context(ctx)?.with_context(ctx)?;
        let fail = get_f64(e, "fail").with_context(ctx)?.with_context(ctx)?;
        let repair = get_f64(e, "repair").with_context(ctx)?.with_context(ctx)?;
        events.push(IngestEvent { proc, fail, repair });
    }
    Ok(IngestRequest { track, n_procs, events })
}

/// `{key}` as the 16-hex-digit wire form.
pub fn key_hex(key: u64) -> String {
    format!("{key:016x}")
}

/// The `select` response body.
pub fn select_response(
    result: &SearchResult,
    key: u64,
    cached: bool,
    lambda: f64,
    theta: f64,
    track: Option<&str>,
    stale: bool,
) -> Json {
    let mut o = Json::obj();
    o.set("ok", Json::from(true))
        .set("interval", Json::from(result.interval))
        .set("uwt", Json::from(result.uwt))
        .set("best_probed", Json::from(result.best_probed))
        .set("evaluations", Json::from(result.evaluations))
        .set(
            "probes",
            Json::Arr(
                result
                    .probes
                    .iter()
                    .map(|&(i, u)| Json::Arr(vec![Json::from(i), Json::from(u)]))
                    .collect(),
            ),
        )
        .set("key", Json::from(key_hex(key)))
        .set("cached", Json::from(cached))
        .set("stale", Json::from(stale))
        .set("lambda", Json::from(lambda))
        .set("theta", Json::from(theta));
    if let Some(t) = track {
        o.set("track", Json::from(t));
    }
    o
}

/// The `GET /v1/explain` response body: a small server envelope (key,
/// rates, staleness) around [`SearchTrace::explain_json`]. The trace
/// fields are emitted verbatim so `scripts/serve_smoke.sh` can diff the
/// payload against `select --json --explain` (only the per-probe
/// `seconds` differ between a daemon run and an offline run).
pub fn explain_response(
    entry_key: u64,
    result: &SearchResult,
    trace: &SearchTrace,
    lambda: f64,
    theta: f64,
    stale: bool,
    track: Option<&str>,
) -> Json {
    let mut o = trace.explain_json(result);
    o.set("ok", Json::from(true))
        .set("key", Json::from(key_hex(entry_key)))
        .set("stale", Json::from(stale))
        .set("lambda", Json::from(lambda))
        .set("theta", Json::from(theta));
    if let Some(t) = track {
        o.set("track", Json::from(t));
    }
    o
}

pub fn error_response(message: &str) -> Json {
    let mut o = Json::obj();
    o.set("ok", Json::from(false)).set("error", Json::from(message));
    o
}

/// One failed `select_batch` item: `results[index]` for the caller, with
/// the index repeated inline so an error is self-describing when logged.
pub fn batch_item_error(index: usize, message: &str) -> Json {
    let mut o = Json::obj();
    o.set("ok", Json::from(false))
        .set("index", Json::from(index))
        .set("error", Json::from(message));
    o
}

/// The `select_batch` response envelope: positional `results`, one per
/// request item.
pub fn select_batch_response(results: Vec<Json>) -> Json {
    let mut o = Json::obj();
    o.set("ok", Json::from(true))
        .set("count", Json::from(results.len()))
        .set("results", Json::Arr(results));
    o
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used, clippy::panic)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Json {
        Json::parse(s).unwrap()
    }

    #[test]
    fn select_minimal_named_system() {
        let r = parse_select(&parse(r#"{"system": "system-1/128"}"#)).unwrap();
        assert_eq!(r.system.n, 128);
        assert_eq!(r.app.name, "QR");
        assert_eq!(r.policy.name, "greedy");
        assert!(r.track.is_none());
        assert_eq!(r.cfg.i_min, SearchConfig::default().i_min);
    }

    #[test]
    fn select_full_object_system() {
        let r = parse_select(&parse(
            r#"{"system": {"n": 6, "lambda": 5.787e-6, "theta": 4.1e-4},
                "app": "md", "policy": "pb",
                "search": {"i_min": 120, "i_max": 90000, "refine_steps": 3, "band": 0.1},
                "track": "c1"}"#,
        ))
        .unwrap();
        assert_eq!(r.system.n, 6);
        assert_eq!(r.app.name, "MD");
        assert_eq!(r.policy.name, "pb");
        assert_eq!(r.cfg.refine_steps, 3);
        assert_eq!(r.cfg.i_min, 120.0);
        assert_eq!(r.track.as_deref(), Some("c1"));
    }

    #[test]
    fn select_mttf_units_and_custom_policy() {
        let r = parse_select(&parse(
            r#"{"system": {"n": 4, "mttf_days": 2, "mttr_min": 45},
                "policy": {"rp": [1, 2, 2, 3]}}"#,
        ))
        .unwrap();
        assert!((r.system.mttf() - 2.0 * 86_400.0).abs() < 1e-9);
        assert_eq!(r.policy.vector(), &[1, 2, 2, 3]);
    }

    #[test]
    fn select_custom_app_vectors() {
        let r = parse_select(&parse(
            r#"{"system": {"n": 3, "lambda": 1e-6, "theta": 1e-3},
                "app": {"name": "x", "work": [1, 1.8, 2.4], "ckpt": [30, 31, 32],
                        "rec_same": 9, "rec_span": 4}}"#,
        ))
        .unwrap();
        assert_eq!(r.app.name, "x");
        assert_eq!(r.app.work_per_sec(2), 1.8);
    }

    #[test]
    fn select_rejections() {
        assert!(parse_select(&parse(r#"{}"#)).is_err());
        assert!(parse_select(&parse(r#"{"system": "nope/999"}"#)).is_err());
        assert!(parse_select(&parse(r#"{"system": {"n": 0, "lambda": 1, "theta": 1}}"#)).is_err());
        assert!(parse_select(&parse(r#"{"system": {"n": 4, "lambda": -1, "theta": 1}}"#)).is_err());
        assert!(parse_select(&parse(r#"{"system": {"n": 4, "lambda": 1e-6}}"#)).is_err());
        assert!(
            parse_select(&parse(r#"{"system": "condor/64", "app": "nope"}"#)).is_err()
        );
        assert!(parse_select(&parse(
            r#"{"system": "condor/64", "search": {"i_min": 0}}"#
        ))
        .is_err());
        assert!(parse_select(&parse(
            r#"{"system": "condor/64", "search": {"band": 1.5}}"#
        ))
        .is_err());
        assert!(parse_select(&parse(
            r#"{"system": {"n": 4, "lambda": 1e-6, "theta": 1e-3}, "policy": {"rp": [1, 2]}}"#
        ))
        .is_err());
        assert!(parse_select(&parse(r#"{"system": "condor/64", "track": ""}"#)).is_err());
    }

    #[test]
    fn ingest_roundtrip_and_rejections() {
        let r = parse_ingest(&parse(
            r#"{"track": "c1", "n_procs": 8,
                "events": [{"proc": 0, "fail": 10.5, "repair": 20}]}"#,
        ))
        .unwrap();
        assert_eq!(r.track, "c1");
        assert_eq!(r.n_procs, Some(8));
        assert_eq!(r.events, vec![IngestEvent { proc: 0, fail: 10.5, repair: 20.0 }]);
        let r = parse_ingest(&parse(r#"{"track": "c1", "events": []}"#)).unwrap();
        assert!(r.n_procs.is_none());
        assert!(r.events.is_empty());
        assert!(parse_ingest(&parse(r#"{"events": []}"#)).is_err());
        assert!(parse_ingest(&parse(r#"{"track": "c1"}"#)).is_err());
        assert!(parse_ingest(&parse(r#"{"track": "c1", "n_procs": 0, "events": []}"#)).is_err());
        assert!(parse_ingest(&parse(
            r#"{"track": "c1", "events": [{"proc": 0, "fail": 1}]}"#
        ))
        .is_err());
        assert!(parse_ingest(&parse(
            r#"{"track": "c1", "events": [{"proc": -1, "fail": 1, "repair": 2}]}"#
        ))
        .is_err());
    }

    #[test]
    fn select_batch_parses_items_and_names_the_bad_one() {
        let reqs = parse_select_batch(&parse(
            r#"{"items": [
                {"system": "system-1/128"},
                {"system": {"n": 4, "mttf_days": 2, "mttr_min": 45}, "app": "md", "track": "c9"}
            ]}"#,
        ))
        .unwrap();
        assert_eq!(reqs.len(), 2);
        assert_eq!(reqs[0].system.n, 128);
        assert_eq!(reqs[1].system.n, 4);
        assert_eq!(reqs[1].app.name, "MD");
        assert_eq!(reqs[1].track.as_deref(), Some("c9"));

        assert!(parse_select_batch(&parse(r#"{}"#)).is_err());
        assert!(parse_select_batch(&parse(r#"{"items": []}"#)).is_err());
        assert!(parse_select_batch(&parse(r#"{"items": 3}"#)).is_err());
        // The failing index travels in the error chain.
        let err = parse_select_batch(&parse(
            r#"{"items": [{"system": "system-1/128"}, {"app": "qr"}]}"#,
        ))
        .unwrap_err();
        assert!(format!("{err:#}").contains("items[1]"), "index lost: {err:#}");
    }

    #[test]
    fn select_batch_response_shape() {
        let resp = select_batch_response(vec![error_response("x"), batch_item_error(1, "boom")]);
        let re = Json::parse(&resp.to_compact()).unwrap();
        assert_eq!(re.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(re.get("count").unwrap().as_f64(), Some(2.0));
        let results = re.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[1].get("index").unwrap().as_f64(), Some(1.0));
        assert_eq!(results[1].get("error").unwrap().as_str(), Some("boom"));
        assert_eq!(results[1].get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn model_request_defaults() {
        let r = parse_model(&parse(r#"{"system": "condor/64"}"#)).unwrap();
        assert_eq!(r.interval, 3_600.0);
        assert!(parse_model(&parse(r#"{"system": "condor/64", "interval": -5}"#)).is_err());
    }

    #[test]
    fn explain_response_wraps_the_trace_verbatim() {
        use crate::search::{ProbePhase, ProbeTrace};
        let res = SearchResult {
            interval: 4_200.0,
            uwt: 7.25,
            best_probed: 4_800.0,
            probes: vec![(300.0, 1.5), (4_800.0, 7.5)],
            evaluations: 2,
        };
        let trace = SearchTrace {
            probes: vec![
                ProbeTrace {
                    interval: 300.0,
                    uwt: 1.5,
                    phase: ProbePhase::Doubling,
                    warm_start: false,
                    solve_iters: 41,
                    seconds: 0.001,
                },
                ProbeTrace {
                    interval: 4_800.0,
                    uwt: 7.5,
                    phase: ProbePhase::Refinement,
                    warm_start: true,
                    solve_iters: 9,
                    seconds: 0.0005,
                },
            ],
        };
        let j = explain_response(0xabcd, &res, &trace, 1.1e-7, 3.7e-4, true, Some("c1"));
        let re = Json::parse(&j.to_compact()).unwrap();
        assert_eq!(re.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(re.get("key").unwrap().as_str(), Some("000000000000abcd"));
        assert_eq!(re.get("stale").unwrap().as_bool(), Some(true));
        assert_eq!(re.get("track").unwrap().as_str(), Some("c1"));
        assert_eq!(re.get("interval").unwrap().as_f64(), Some(res.interval));
        assert_eq!(re.get("evaluations").unwrap().as_f64(), Some(2.0));
        let probes = re.get("probes").unwrap().as_arr().unwrap();
        assert_eq!(probes.len(), 2);
        assert_eq!(probes[0].get("phase").unwrap().as_str(), Some("doubling"));
        assert_eq!(probes[0].get("warm").unwrap().as_bool(), Some(false));
        assert_eq!(probes[0].get("iters").unwrap().as_f64(), Some(41.0));
        assert_eq!(probes[1].get("phase").unwrap().as_str(), Some("refinement"));
        assert_eq!(probes[1].get("warm").unwrap().as_bool(), Some(true));
        assert_eq!(probes[1].get("interval").unwrap().as_f64(), Some(4_800.0));
    }

    #[test]
    fn responses_roundtrip_floats_exactly() {
        let res = SearchResult {
            interval: 6_517.333333333333,
            uwt: 9.123456789012345,
            best_probed: 4_800.0,
            probes: vec![(300.0, 1.5), (600.0, 2.5)],
            evaluations: 2,
        };
        let j = select_response(&res, 0xabcd, true, 1.1e-7, 3.7e-4, Some("c1"), false);
        let re = Json::parse(&j.to_compact()).unwrap();
        assert_eq!(re.get("interval").unwrap().as_f64(), Some(res.interval));
        assert_eq!(re.get("uwt").unwrap().as_f64(), Some(res.uwt));
        assert_eq!(re.get("cached").unwrap().as_bool(), Some(true));
        assert_eq!(re.get("key").unwrap().as_str(), Some("000000000000abcd"));
        assert_eq!(re.get("track").unwrap().as_str(), Some("c1"));
        assert_eq!(re.get("probes").unwrap().as_arr().unwrap().len(), 2);
        let err = error_response("bad");
        assert_eq!(err.get("ok").unwrap().as_bool(), Some(false));
    }
}
