//! Streaming failure ingestion and the online exponential-rate estimator.
//!
//! Each ingest-tracked system (a client-chosen `track` id) accumulates its
//! completed outages in a [`TraceTail`] — the appendable merged timeline of
//! `traces::index`, which absorbs out-of-order and retransmitted reports
//! deterministically (see its ingest contract). After every accepted batch
//! the tail's window is **re-fitted**:
//!
//! * `λ̂` — ordinary least squares (via [`fitting::least_squares`]) of the
//!   cumulative failure count against the failure times in the window; the
//!   slope is the system-wide failure rate, divided by the processor count
//!   for the per-processor `λ` (exact when all processors are up, and
//!   MTTR ≪ MTTF keeps the bias negligible — the same regime the paper's
//!   exponential model assumes);
//! * `θ̂` — OLS of cumulative downtime against the count of outages
//!   completed in the window; the slope is the windowed MTTR.
//!
//! Both are plain linear regressions rather than the full-history MLE of
//! [`crate::traces::stats::estimate_rates`] on purpose: the window slides,
//! so the estimator must forget — a rate shift two windows ago should not
//! drag on today's recommendation.
//!
//! When the re-fit moves beyond the configured **relative drift
//! threshold** against the rates a cached recommendation was computed
//! with (`max(|λ̂/λ − 1|, |θ̂/θ − 1|)`), the advisor marks the entry stale
//! and re-selects in the background (see [`crate::advisor`]).

use anyhow::{bail, ensure, Context, Result};

use crate::fitting::least_squares;
use crate::markov::ModelInputs;
use crate::search::SearchConfig;
use crate::store::{SpecRecord, TrackStore, WalRecord};
use crate::traces::index::TraceTail;
use crate::traces::ShardedIndex;
use crate::util::pool;

/// One completed outage reported to `ingest`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IngestEvent {
    pub proc: usize,
    /// Failure instant, seconds (the track's own clock).
    pub fail: f64,
    /// Repair completion, seconds; must exceed `fail`.
    pub repair: f64,
}

/// A recommendation registered under a track: enough to re-run the
/// selection when the rates drift.
pub struct TrackedSpec {
    /// Cache key the current recommendation lives under.
    pub key: u64,
    /// Inputs as last selected (system rates included).
    pub inputs: ModelInputs,
    pub cfg: SearchConfig,
    /// Rates the current recommendation was computed with — the drift
    /// reference.
    pub rates_used: (f64, f64),
    /// A background re-selection is in flight; drift checks are paused
    /// until it lands.
    pub pending: bool,
}

/// Per-system ingest state.
pub struct Track {
    pub n_procs: usize,
    pub tail: TraceTail,
    /// Latest windowed re-fit, if the window has enough data.
    pub rates: Option<(f64, f64)>,
    pub specs: Vec<TrackedSpec>,
    /// Outages accepted / merged-as-duplicate (survives restarts when the
    /// track is persisted).
    pub accepted: u64,
    pub merged: u64,
    /// Completed background re-selections.
    pub reselects: u64,
    /// Events dropped by the retention cap (2 per evicted outage).
    pub evicted: u64,
    /// Durable backing, when the daemon runs with `--data-dir`. All
    /// mutations under the track lock also append here, so the WAL order
    /// equals the apply order and replay reproduces this struct exactly.
    pub store: Option<TrackStore>,
    /// Shared sharded view of the tail, rebuilt by [`Track::refit`] on
    /// an amortized schedule (see [`Track::refresh_sharded`] — never a
    /// full rebuild per ingest batch, and a stale index is freed
    /// immediately rather than sitting on ~2x-tail memory). A re-fit
    /// scans it whenever it is current and falls back to the monolithic
    /// index otherwise; the two scans are pinned float-identical, so the
    /// route never changes the fitted rates (ROADMAP "sharded simulator
    /// adoption"). In-memory only; recovery leaves it `None`.
    pub sharded: Option<ShardedView>,
}

/// The cached sharded view of a track's tail and its build point.
pub struct ShardedView {
    /// [`TraceTail::generation`] when the view was built.
    pub generation: u64,
    /// Shard window the view was built with, seconds.
    pub window: f64,
    /// Tail events at build time — the rebuild-schedule reference.
    pub built_events: usize,
    /// The compiled view while current; freed the moment the tail
    /// mutates past it (the schedule metadata above survives).
    pub index: Option<ShardedIndex>,
}

impl Track {
    pub fn new(n_procs: usize) -> Result<Track> {
        Ok(Track {
            n_procs,
            tail: TraceTail::new(n_procs)?,
            rates: None,
            specs: Vec::new(),
            accepted: 0,
            merged: 0,
            reselects: 0,
            evicted: 0,
            store: None,
            sharded: None,
        })
    }

    /// Maintain the shared sharded view on an **amortized schedule**:
    /// rebuild (parallel shard sorts on the pool) only on the first
    /// build, a window change, a tail that doubled or halved since the
    /// last build, or after ~a quarter of the tail's events worth of
    /// mutations — each rebuild costs O(E log E/S) and happens at most
    /// once per Ω(E) mutations, so the amortized rebuild work is
    /// O(log E) per ingested event and a `/v1/ingest` batch never pays a
    /// full rebuild just to re-fit. The refit right after a rebuild
    /// scans the fresh view; between rebuilds a mutated tail leaves the
    /// view stale — its index is **freed immediately** (never a resident
    /// 2x-tail copy) and [`Track::refit`] scans the monolithic index
    /// instead (pinned float-identical). A non-positive window drops the
    /// view entirely.
    fn refresh_sharded(&mut self, shard_window: f64) {
        let n = self.tail.n_events();
        if !(shard_window.is_finite() && shard_window > 0.0) || n == 0 {
            self.sharded = None;
            return;
        }
        let generation = self.tail.generation();
        let rebuild = match &self.sharded {
            Some(v) if v.window != shard_window => true,
            Some(v) if v.generation == generation => false, // current
            Some(v) => {
                let mutations = generation - v.generation;
                n >= v.built_events.saturating_mul(2)
                    || n * 2 <= v.built_events
                    || mutations.saturating_mul(4) >= v.built_events.max(64) as u64
            }
            None => true,
        };
        if rebuild {
            let index = ShardedIndex::from_tail(&self.tail, shard_window, pool::default_workers())
                .expect("window validated positive and finite");
            self.sharded = Some(ShardedView {
                generation,
                window: shard_window,
                built_events: n,
                index: Some(index),
            });
        } else if let Some(v) = &mut self.sharded {
            if v.generation != generation {
                v.index = None; // stale: free it now, keep the schedule
            }
        }
    }

    /// Fold a batch into the tail. Validation is per event: an invalid
    /// event fails the call naming its index, but the valid events before
    /// it stay applied and **are counted** (the error message carries the
    /// partial counts; `status` stays consistent with the tail). Exact
    /// duplicates merge silently (and are still logged — replay needs them
    /// to reproduce the merged counter). Returns `(accepted, merged)` on a
    /// fully clean batch.
    pub fn ingest(&mut self, events: &[IngestEvent]) -> Result<(usize, usize)> {
        let mut accepted = 0usize;
        let mut merged = 0usize;
        let mut result = Ok(());
        for (i, e) in events.iter().enumerate() {
            match self.tail.push(e.proc, e.fail, e.repair) {
                Ok(was_new) => {
                    if was_new {
                        accepted += 1;
                    } else {
                        merged += 1;
                    }
                    if let Some(store) = &mut self.store {
                        if let Err(err) = store
                            .append(&WalRecord::Outage { proc: e.proc, fail: e.fail, repair: e.repair })
                        {
                            // The event is applied in memory but not
                            // durable: fail the batch loudly so the client
                            // retries (a retry merges idempotently).
                            result = Err(err.context(format!(
                                "event {i} applied but not persisted ({accepted} accepted, {merged} merged)"
                            )));
                            break;
                        }
                    }
                }
                Err(err) => {
                    result = Err(err.context(format!(
                        "event {i} (prior events stay applied: {accepted} accepted, {merged} merged)"
                    )));
                    break;
                }
            }
        }
        self.accepted += accepted as u64;
        self.merged += merged as u64;
        self.flush_store()?;
        result.map(|()| (accepted, merged))
    }

    /// Windowed re-fit over the tail (see the module docs); updates,
    /// persists and returns `self.rates` when the window holds at least
    /// `min_failures` failures, leaves them untouched otherwise. The only
    /// error is a persistence failure. The failure-time scan goes through
    /// the track's shared [`ShardedIndex`] view (shard width
    /// `shard_window`, the advisor's retention window) whenever the view
    /// is current — rebuilt on the geometric schedule of
    /// [`Track::refresh_sharded`] — and through the monolithic index
    /// otherwise; the two are pinned equal float for float, so the route
    /// never changes the fitted rates.
    pub fn refit(
        &mut self,
        window: f64,
        min_failures: usize,
        shard_window: f64,
    ) -> Result<Option<(f64, f64)>> {
        self.refresh_sharded(shard_window);
        let fitted = match &self.sharded {
            Some(ShardedView { generation, index: Some(ix), .. })
                if *generation == self.tail.generation() =>
            {
                refit_rates_sharded(&self.tail, ix, window, min_failures)
            }
            _ => refit_rates(&self.tail, window, min_failures),
        };
        match fitted {
            Ok(r) => {
                self.rates = Some(r);
                if let Some(store) = &mut self.store {
                    store.append(&WalRecord::Refit { lambda: r.0, theta: r.1 })?;
                    store.flush()?;
                }
                Ok(Some(r))
            }
            Err(_) => Ok(None),
        }
    }

    /// Enforce the per-track event-retention cap: while the tail holds
    /// more than `max_events` events, evict whole time windows (width
    /// `window` seconds, the shard boundary) from the oldest end — never
    /// touching the window holding the newest event. Each eviction is
    /// logged, so replay reproduces the surviving tail exactly. Returns
    /// the events evicted by this call. `max_events == 0` disables the cap.
    pub fn enforce_retention(&mut self, max_events: usize, window: f64) -> Result<usize> {
        if max_events == 0 || !window.is_finite() || window <= 0.0 {
            return Ok(0);
        }
        let mut removed_total = 0usize;
        'evict: while self.tail.n_events() > max_events {
            let (Some(first), Some(last)) = (self.tail.first_event_time(), self.tail.last_event_time())
            else {
                break;
            };
            let newest_boundary = (last / window).floor() * window;
            let mut cutoff = ((first / window).floor() + 1.0) * window;
            loop {
                if cutoff > newest_boundary {
                    // Only the newest window is left; the cap yields to it
                    // rather than evicting live history.
                    break 'evict;
                }
                let removed = self.tail.evict_before(cutoff);
                if removed > 0 {
                    removed_total += removed;
                    self.evicted += removed as u64;
                    if let Some(store) = &mut self.store {
                        store.append(&WalRecord::Evict { cutoff })?;
                    }
                    break;
                }
                // The oldest outage spans past this boundary; widen.
                cutoff += window;
            }
        }
        if removed_total > 0 {
            self.flush_store()?;
        }
        Ok(removed_total)
    }

    /// Persist a registered (or refreshed) recommendation.
    pub fn record_spec(&mut self, spec: SpecRecord) -> Result<()> {
        if let Some(store) = &mut self.store {
            store.append(&WalRecord::Recommendation(Box::new(spec)))?;
            store.flush()?;
        }
        Ok(())
    }

    fn flush_store(&mut self) -> Result<()> {
        match &mut self.store {
            Some(store) => store.flush(),
            None => Ok(()),
        }
    }
}

/// Windowed `(λ̂, θ̂)` re-fit over the last `window` seconds of the tail,
/// scanning the monolithic index — the oracle
/// [`refit_rates_sharded`] is pinned against.
pub fn refit_rates(tail: &TraceTail, window: f64, min_failures: usize) -> Result<(f64, f64)> {
    let t0 = window_start(tail, window)?;
    let fails: Vec<f64> = tail
        .index()
        .events_since(t0)
        .filter(|&(_, _, repair)| !repair)
        .map(|(t, _, _)| t)
        .collect();
    refit_from_window(tail, fails, t0, min_failures)
}

/// [`refit_rates`] over a shared sharded view of the same tail
/// ([`ShardedIndex::from_tail`]): the failure-time scan touches only the
/// shards overlapping the window. Identical floats by construction
/// (`events_since` is pinned element-equal), asserted by the unit test
/// below.
pub fn refit_rates_sharded(
    tail: &TraceTail,
    index: &ShardedIndex,
    window: f64,
    min_failures: usize,
) -> Result<(f64, f64)> {
    let t0 = window_start(tail, window)?;
    let fails: Vec<f64> = index
        .events_since(t0)
        .filter(|&(_, _, repair)| !repair)
        .map(|(t, _, _)| t)
        .collect();
    refit_from_window(tail, fails, t0, min_failures)
}

fn window_start(tail: &TraceTail, window: f64) -> Result<f64> {
    ensure!(window > 0.0 && window.is_finite(), "window must be positive and finite");
    let end = tail.last_event_time().context("no events ingested yet")?;
    Ok((end - window).max(0.0))
}

/// The shared fit core: λ̂ from the window's failure times, θ̂ from its
/// completed outages.
fn refit_from_window(
    tail: &TraceTail,
    fails: Vec<f64>,
    t0: f64,
    min_failures: usize,
) -> Result<(f64, f64)> {
    // λ̂: slope of cumulative failure count over failure time.
    let need = min_failures.max(2);
    if fails.len() < need {
        bail!("window holds {} failures, need {need}", fails.len());
    }
    let design: Vec<Vec<f64>> = fails.iter().map(|&t| vec![1.0, t - t0]).collect();
    let counts: Vec<f64> = (1..=fails.len()).map(|i| i as f64).collect();
    let beta = least_squares(&design, &counts).context("failure-count fit")?;
    ensure!(beta[1] > 0.0, "non-positive failure-rate slope {}", beta[1]);
    let lambda = beta[1] / tail.n_procs() as f64;

    // θ̂: slope of cumulative downtime over completed-outage count.
    let completed = tail.completed_since(t0);
    if completed.len() < 2 {
        bail!("window holds {} completed outages, need 2", completed.len());
    }
    let mut cum = 0.0f64;
    let mut down: Vec<f64> = Vec::with_capacity(completed.len());
    for &(_, dur) in &completed {
        cum += dur;
        down.push(cum);
    }
    let design: Vec<Vec<f64>> =
        (1..=completed.len()).map(|j| vec![1.0, j as f64]).collect();
    let beta = least_squares(&design, &down).context("downtime fit")?;
    ensure!(beta[1] > 0.0, "non-positive MTTR slope {}", beta[1]);
    Ok((lambda, 1.0 / beta[1]))
}

/// Relative drift between the rates a recommendation used and a fresh
/// re-fit: `max(|λ̂/λ − 1|, |θ̂/θ − 1|)`.
pub fn relative_drift(used: (f64, f64), fresh: (f64, f64)) -> f64 {
    let dl = (fresh.0 / used.0 - 1.0).abs();
    let dt = (fresh.1 / used.1 - 1.0).abs();
    dl.max(dt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traces::synth::{generate, SynthSpec};
    use crate::util::rng::Rng;

    const DAY: f64 = 86_400.0;

    fn tracked_tail(n: usize, lam: f64, theta: f64, days: f64, seed: u64) -> Track {
        let mut rng = Rng::new(seed);
        let trace = generate(&SynthSpec::exponential(n, lam, theta, days * DAY), &mut rng);
        let mut track = Track::new(n).unwrap();
        let events: Vec<IngestEvent> = (0..n)
            .flat_map(|p| {
                trace
                    .outages(p)
                    .iter()
                    .map(move |&(fail, repair)| IngestEvent { proc: p, fail, repair })
            })
            .collect();
        track.ingest(&events).unwrap();
        track
    }

    #[test]
    fn refit_recovers_generator_rates() {
        let (lam, theta) = (1.0 / (2.0 * DAY), 1.0 / 2_400.0);
        let track = tracked_tail(8, lam, theta, 120.0, 5);
        let (lh, th) = refit_rates(&track.tail, 120.0 * DAY, 8).unwrap();
        // OLS over hundreds of events: ~4% typical error, calibrated
        // against a reference implementation; 25% is a safe gate.
        assert!((lh / lam - 1.0).abs() < 0.25, "λ̂ {lh} vs λ {lam}");
        assert!((th / theta - 1.0).abs() < 0.25, "θ̂ {th} vs θ {theta}");
    }

    #[test]
    fn refit_window_sees_recent_rate_shift() {
        // 60 volatile days appended after 60 reliable days: the windowed
        // fit over the recent half must report the volatile rate.
        let (lam_old, lam_new, theta) = (1.0 / (8.0 * DAY), 1.0 / DAY, 1.0 / 2_400.0);
        let mut track = tracked_tail(8, lam_old, theta, 60.0, 6);
        let mut rng = Rng::new(7);
        let shifted = generate(&SynthSpec::exponential(8, lam_new, theta, 60.0 * DAY), &mut rng);
        for p in 0..8 {
            for &(f, r) in shifted.outages(p) {
                track.tail.push(p, f + 60.0 * DAY, r + 60.0 * DAY).unwrap();
            }
        }
        let (lh, _) = refit_rates(&track.tail, 55.0 * DAY, 8).unwrap();
        assert!(
            (lh / lam_new - 1.0).abs() < 0.3,
            "windowed λ̂ {lh} should track the recent rate {lam_new}, not {lam_old}"
        );
        assert!(relative_drift((lam_old, theta), (lh, theta)) > 2.0);
    }

    #[test]
    fn refit_requires_enough_failures() {
        let mut track = Track::new(4).unwrap();
        assert!(refit_rates(&track.tail, DAY, 2).is_err());
        track.tail.push(0, 100.0, 200.0).unwrap();
        track.tail.push(1, 300.0, 350.0).unwrap();
        assert!(refit_rates(&track.tail, DAY, 8).is_err(), "below min_failures");
        assert!(refit_rates(&track.tail, DAY, 2).is_ok());
        assert!(refit_rates(&track.tail, -1.0, 2).is_err());
    }

    #[test]
    fn track_ingest_counts_and_refit() {
        let mut track = Track::new(2).unwrap();
        let batch = [
            IngestEvent { proc: 0, fail: 100.0, repair: 160.0 },
            IngestEvent { proc: 1, fail: 500.0, repair: 540.0 },
            IngestEvent { proc: 0, fail: 900.0, repair: 980.0 },
            IngestEvent { proc: 0, fail: 100.0, repair: 160.0 }, // retransmission
        ];
        let (accepted, merged) = track.ingest(&batch).unwrap();
        assert_eq!((accepted, merged), (3, 1));
        assert_eq!((track.accepted, track.merged), (3, 1));
        assert!(track.refit(10_000.0, 2, 1_000.0).unwrap().is_some());
        let (lh, th) = track.rates.unwrap();
        assert!(lh > 0.0 && th > 0.0);
        // Below min_failures the previous rates stay.
        assert!(track.refit(10_000.0, 50, 1_000.0).unwrap().is_none());
        assert_eq!(track.rates, Some((lh, th)));
        // A conflicting event fails the batch; valid events before it
        // stay applied and counted.
        let bad = [
            IngestEvent { proc: 1, fail: 2_000.0, repair: 2_100.0 },
            IngestEvent { proc: 0, fail: 100.0, repair: 170.0 },
        ];
        let err = track.ingest(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("event 1"), "error should name the event: {err:#}");
        assert_eq!((track.accepted, track.merged), (4, 1), "prior valid event not counted");
    }

    #[test]
    fn retention_cap_evicts_oldest_windows_only() {
        let mut track = Track::new(2).unwrap();
        // Three 1000-second windows: [0,1000), [1000,2000), [5000,6000).
        let batch = [
            IngestEvent { proc: 0, fail: 100.0, repair: 200.0 },
            IngestEvent { proc: 1, fail: 300.0, repair: 400.0 },
            IngestEvent { proc: 0, fail: 1_100.0, repair: 1_200.0 },
            IngestEvent { proc: 1, fail: 5_100.0, repair: 5_200.0 },
            IngestEvent { proc: 0, fail: 5_300.0, repair: 5_400.0 },
        ];
        track.ingest(&batch).unwrap();
        assert_eq!(track.tail.n_events(), 10);
        // Cap disabled: nothing happens.
        assert_eq!(track.enforce_retention(0, 1_000.0).unwrap(), 0);
        // Cap 6: evict the oldest window (4 events), which suffices.
        assert_eq!(track.enforce_retention(6, 1_000.0).unwrap(), 4);
        assert_eq!(track.tail.n_events(), 6);
        assert_eq!(track.evicted, 4);
        assert_eq!(track.tail.first_event_time(), Some(1_100.0));
        // Cap 2: the middle window goes too, but the newest window stays
        // even though it still exceeds the cap.
        assert_eq!(track.enforce_retention(2, 1_000.0).unwrap(), 2);
        assert_eq!(track.tail.n_events(), 4);
        assert_eq!(track.enforce_retention(2, 1_000.0).unwrap(), 0, "newest window is immune");
        assert_eq!(track.evicted, 6);
    }

    #[test]
    fn retention_skips_windows_spanned_by_open_outages() {
        let mut track = Track::new(2).unwrap();
        // The oldest outage spans from window 0 deep into window 4.
        track.tail.push(0, 100.0, 4_500.0).unwrap();
        track.tail.push(1, 4_600.0, 4_700.0).unwrap();
        track.tail.push(0, 9_100.0, 9_200.0).unwrap();
        // Cutoffs at 1000/2000/... remove nothing until 5000, which drops
        // both outages repaired before it.
        assert_eq!(track.enforce_retention(2, 1_000.0).unwrap(), 4);
        assert_eq!(track.tail.n_events(), 2);
        assert_eq!(track.tail.first_event_time(), Some(9_100.0));
    }

    #[test]
    fn sharded_refit_matches_monolithic_exactly() {
        let (lam, theta) = (1.0 / (2.0 * DAY), 1.0 / 2_400.0);
        let mut track = tracked_tail(8, lam, theta, 90.0, 9);
        let window = 40.0 * DAY;
        let mono = refit_rates(&track.tail, window, 8).unwrap();
        for shard_window in [0.5 * DAY, 7.0 * DAY, 1_000.0 * DAY] {
            let index = ShardedIndex::from_tail(&track.tail, shard_window, 4).unwrap();
            let sharded = refit_rates_sharded(&track.tail, &index, window, 8).unwrap();
            assert_eq!(mono, sharded, "sharded re-fit diverged at shard window {shard_window}");
        }
        // Track::refit routes through the shared view and lands the same
        // rates; an unchanged tail reuses the build.
        assert_eq!(track.refit(window, 8, 7.0 * DAY).unwrap(), Some(mono));
        let view = track.sharded.as_ref().expect("first refit builds the view");
        let (gen_before, built) = (view.generation, view.built_events);
        assert_eq!(built, track.tail.n_events());
        assert!(view.index.is_some(), "a current view keeps its index");
        track.refit(window, 8, 7.0 * DAY).unwrap();
        assert_eq!(
            track.sharded.as_ref().unwrap().generation,
            gen_before,
            "unchanged tail must not rebuild the sharded view"
        );
        // A small mutation stales the view: no rebuild, the index is
        // freed immediately, and the re-fit falls back to the monolithic
        // scan — identical rates either way.
        track.tail.push(0, 100.0 * DAY, 100.0 * DAY + 60.0).unwrap();
        let after_push = track.refit(window, 8, 7.0 * DAY).unwrap().unwrap();
        assert_eq!(after_push, refit_rates(&track.tail, window, 8).unwrap());
        let view = track.sharded.as_ref().unwrap();
        assert_eq!(view.generation, gen_before, "one mutation must not trigger a rebuild");
        assert!(view.index.is_none(), "a stale view must free its index");
        // Enough mutations cross the amortized threshold: rebuilt fresh,
        // and that refit scans the sharded view again.
        let mut t = 101.0 * DAY;
        while track.tail.n_events() < 2 * built {
            track.tail.push(1, t, t + 120.0).unwrap();
            t += 3_600.0;
        }
        track.refit(window, 8, 7.0 * DAY).unwrap();
        let view = track.sharded.as_ref().unwrap();
        assert_eq!(view.generation, track.tail.generation(), "grown tail must rebuild");
        assert_eq!(view.built_events, track.tail.n_events());
        assert!(view.index.is_some());
    }

    #[test]
    fn drift_metric() {
        let base = (1e-6, 1e-3);
        assert!(relative_drift(base, base) < 1e-15);
        assert!((relative_drift(base, (2e-6, 1e-3)) - 1.0).abs() < 1e-12);
        assert!((relative_drift(base, (1e-6, 0.5e-3)) - 0.5).abs() < 1e-12);
        assert!((relative_drift(base, (0.5e-6, 1.5e-3)) - 0.5).abs() < 1e-12);
    }
}
