#![deny(unsafe_code)]
//! # malleable-ckpt
//!
//! Reproduction of **"Determination of Checkpointing Intervals for Malleable
//! Applications"** (K. Raghavendra & Sathish S. Vadhiyar, 2017) as a
//! three-layer Rust + JAX/Pallas system.
//!
//! A *malleable* parallel application can change its processor count at
//! every recovery. This library builds the paper's Markov performance model
//! `M^mall`, estimates the **useful work per unit time (UWT)** an
//! application achieves in the presence of failures as a function of the
//! checkpointing interval `I`, and selects the interval maximizing UWT. A
//! trace-driven simulator evaluates the selected intervals exactly as the
//! paper's §VI does.
//!
//! ## Layering
//!
//! * **Layer 1/2 (build time)** — JAX + Pallas kernels compute the
//!   birth–death transition matrices (`expm`, resolvents) and are AOT
//!   lowered to HLO text (`artifacts/`).
//! * **Layer 3 (this crate)** — everything else: state-space construction,
//!   sparse transition assembly, stationary analysis, interval search,
//!   rescheduling policies, the simulator, baselines and the experiment
//!   harness. The [`runtime`] module executes the AOT artifacts through the
//!   PJRT CPU client (behind the `pjrt` cargo feature); [`linalg`] provides
//!   a native oracle/fallback.
//!
//! ## Evaluation engine
//!
//! The paper's results come from "a large number of simulations", so the
//! evaluation path is engineered as a pipeline of compiled indices and
//! incremental builders, each with its seed implementation preserved as an
//! exactness oracle (`rust/tests/engine_equivalence.rs` pins optimized ==
//! seed, float for float):
//!
//! * [`traces::TraceIndex`] compiles a failure trace once into a merged,
//!   sorted global event timeline with an availability step function and
//!   per-processor cursors; [`simulator::Simulator::run`] walks it with
//!   amortized O(1), zero-allocation queries ([`simulator::Simulator::run_reference`]
//!   is the seed path).
//! * [`markov::ModelBuilder`] caches everything about `M^mall` that does
//!   not depend on the checkpointing interval — state space, resolvent
//!   bands, and every up-state row of `P^mall` — so each
//!   [`search::select_interval`] probe only refreshes the `δ`-dependent
//!   rates and re-solves ([`search::select_interval_uncached`] rebuilds per
//!   probe).
//! * Sweeps and experiment segments fan out over the [`util::pool`] scoped
//!   thread pool ([`simulator::Simulator::sweep_par`],
//!   [`experiments::common::run_segments`]); RNG draws are made serially
//!   up front so parallel results are bit-identical to the serial ones.
//! * `cargo bench --bench perf` tracks all of it and writes a
//!   machine-readable `BENCH_perf.json` at the repo root (`make
//!   bench-smoke` for the CI-sized grid).
//!
//! ## Batch-first selection API
//!
//! [`api`] is the one front door to the interval search:
//! [`api::SelectSpec`] captures the full canonical request tuple
//! (system, app cost vectors, policy vector, search shape, build
//! options) and [`api::SelectBatch`] validates every spec up front,
//! dedupes identical specs by canonical hash (one build answers all
//! duplicates), fans the unique specs out over [`util::pool`] — one
//! [`markov::SharedBuilder`] per unique spec, π warm-started across its
//! probes — and returns per-spec outcomes in input order with per-item
//! errors. Every caller resolves through it: the CLI `select`
//! subcommand, the advisor's `/v1/select` and `/v1/select_batch`
//! endpoints, the experiment sweeps and the perf bench. Batch results
//! are pinned item-for-item to the singleton [`search::select_interval`]
//! oracle (interval exact, UWT within 1e-9 relative) by
//! `rust/tests/engine_equivalence.rs`.
//!
//! ## Advisor service (Layer 4)
//!
//! [`advisor`] keeps the machinery above alive as a long-running
//! recommendation daemon (`malleable-ckpt serve`): a sharded,
//! LRU-budgeted cache of [`markov::SharedBuilder`]s keyed by a canonical
//! spec hash answers repeat `select`s in O(1); streaming failure
//! ingestion re-fits per-system rates over an appendable
//! [`traces::index::TraceTail`] and re-selects in the background — with
//! the stationary solve warm-started from the previous recommendation —
//! when the rates drift beyond a configurable threshold. With
//! `--data-dir`, [`store`] makes every track durable: an append-only
//! checksummed WAL plus atomically-replaced snapshots replay the exact
//! pre-crash state on boot (torn tails truncated), and
//! [`traces::ShardedIndex`] partitions the merged event timeline by time
//! window so segment evaluations touch only their shard and index builds
//! parallelize over [`util::pool`].

pub mod advisor;
pub mod analysis;
pub mod api;
pub mod apps;
pub mod baselines;
pub mod config;
pub mod experiments;
pub mod fitting;
pub mod fuzz;
pub mod linalg;
pub mod markov;
pub mod metrics;
pub mod obs;
pub mod policies;
pub mod runtime;
pub mod search;
pub mod simulator;
pub mod store;
pub mod traces;
pub mod util;

/// Convenient re-exports of the types most users need.
pub mod prelude {
    pub use crate::api::{SelectBatch, SelectSpec};
    pub use crate::apps::AppProfile;
    pub use crate::config::SystemParams;
    pub use crate::markov::{MalleableModel, ModelInputs};
    pub use crate::policies::ReschedulingPolicy;
    pub use crate::runtime::ComputeEngine;
    pub use crate::search::{self, SearchConfig};
    pub use crate::simulator::{SimConfig, Simulator};
    pub use crate::traces::FailureTrace;
}
