//! # malleable-ckpt
//!
//! Reproduction of **"Determination of Checkpointing Intervals for Malleable
//! Applications"** (K. Raghavendra & Sathish S. Vadhiyar, 2017) as a
//! three-layer Rust + JAX/Pallas system.
//!
//! A *malleable* parallel application can change its processor count at
//! every recovery. This library builds the paper's Markov performance model
//! `M^mall`, estimates the **useful work per unit time (UWT)** an
//! application achieves in the presence of failures as a function of the
//! checkpointing interval `I`, and selects the interval maximizing UWT. A
//! trace-driven simulator evaluates the selected intervals exactly as the
//! paper's §VI does.
//!
//! ## Layering
//!
//! * **Layer 1/2 (build time)** — JAX + Pallas kernels compute the
//!   birth–death transition matrices (`expm`, resolvents) and are AOT
//!   lowered to HLO text (`artifacts/`).
//! * **Layer 3 (this crate)** — everything else: state-space construction,
//!   sparse transition assembly, stationary analysis, interval search,
//!   rescheduling policies, the simulator, baselines and the experiment
//!   harness. The [`runtime`] module executes the AOT artifacts through the
//!   PJRT CPU client; [`linalg`] provides a native oracle/fallback.

pub mod apps;
pub mod baselines;
pub mod config;
pub mod experiments;
pub mod fitting;
pub mod linalg;
pub mod markov;
pub mod metrics;
pub mod policies;
pub mod runtime;
pub mod search;
pub mod simulator;
pub mod traces;
pub mod util;

/// Convenient re-exports of the types most users need.
pub mod prelude {
    pub use crate::apps::AppProfile;
    pub use crate::config::SystemParams;
    pub use crate::markov::{MalleableModel, ModelInputs};
    pub use crate::policies::ReschedulingPolicy;
    pub use crate::runtime::ComputeEngine;
    pub use crate::search::{self, SearchConfig};
    pub use crate::simulator::{SimConfig, Simulator};
    pub use crate::traces::FailureTrace;
}
