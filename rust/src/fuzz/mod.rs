//! Structured fuzzing of every byte-level parser the daemon trusts —
//! the `malleable-ckpt fuzz {http,wal,snapshot,replicate}` subcommand
//! (DESIGN.md §12).
//!
//! Each target starts from **valid seed bytes** (a well-formed HTTP/1.1
//! request frame, a WAL image with every record kind, an encoded
//! snapshot) and applies deterministic [`crate::util::rng`]-driven
//! mutations: truncations at arbitrary offsets, bit flips, length-field
//! lies, header/frame splices, duplicated and pipelined garbage. The
//! mutated input is then fed to the real production parser under
//! [`std::panic::catch_unwind`].
//!
//! The invariant is the robustness contract of DESIGN.md §12: **every
//! input produces a clean parse or a typed error — never a panic and
//! never an allocation proportional to a length field the input merely
//! *claims*.** Mutated inputs are bounded (seed size + a small splice
//! budget), so any blow-up an iteration could observe would have to come
//! from trusting a lied length.
//!
//! Determinism: `fuzz <target> --iters N --seed S` replays identically —
//! iteration `i` derives its mutations from `Rng::new(seed).fork()`
//! chains only, so a CI failure reproduces locally from the two numbers
//! in the log line.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::advisor::protocol;
use crate::advisor::replicate;
use crate::advisor::server::try_parse_request;
use crate::apps::AppProfile;
use crate::config::SystemParams;
use crate::markov::ModelInputs;
use crate::policies::ReschedulingPolicy;
use crate::search::SearchConfig;
use crate::store::wal::{self, encode_frame, SpecRecord, WalRecord, WAL_MAGIC};
use crate::store::{snapshot, TrackState};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// What one fuzz run drove and what came back.
#[derive(Debug, Clone)]
pub struct FuzzReport {
    pub target: FuzzTarget,
    pub iters: u64,
    /// Inputs the parser accepted cleanly.
    pub accepted: u64,
    /// Inputs rejected with a typed error (or a torn-tail stop).
    pub rejected: u64,
    /// Inputs that panicked the parser — any is a bug.
    pub panics: u64,
    /// `(iteration, payload)` of the first panic, for reproduction.
    pub first_panic: Option<(u64, String)>,
}

impl FuzzReport {
    /// `Err` with a reproduction recipe when any iteration panicked.
    pub fn into_result(self, seed: u64) -> Result<FuzzReport> {
        if self.panics > 0 {
            let (iter, msg) = self.first_panic.clone().unwrap_or((0, "?".into()));
            return Err(anyhow!(
                "fuzz {}: {} panic(s) in {} iters; first at iter {iter} ({msg}); \
                 reproduce with --seed {seed} --iters {}",
                self.target.name(),
                self.panics,
                self.iters,
                self.iters,
            ));
        }
        Ok(self)
    }
}

/// The parser a fuzz run attacks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FuzzTarget {
    /// HTTP/1.1 request framing + the JSON protocol parsers.
    Http,
    /// The WAL scanner ([`wal::scan_bytes`]).
    Wal,
    /// The snapshot decoder ([`snapshot::decode`]).
    Snapshot,
    /// The replication manifest/segment parsers and the replica's
    /// install-side segment validator ([`crate::advisor::replicate`]).
    Replicate,
    /// The srclint analyzer ([`crate::analysis`]): its lexer must stay
    /// total on arbitrary bytes decoded as lossy UTF-8.
    Srclint,
}

impl FuzzTarget {
    pub fn from_name(name: &str) -> Result<FuzzTarget> {
        match name {
            "http" => Ok(FuzzTarget::Http),
            "wal" => Ok(FuzzTarget::Wal),
            "snapshot" => Ok(FuzzTarget::Snapshot),
            "replicate" => Ok(FuzzTarget::Replicate),
            "srclint" => Ok(FuzzTarget::Srclint),
            other => Err(anyhow!(
                "unknown fuzz target '{other}' (http | wal | snapshot | replicate | srclint)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FuzzTarget::Http => "http",
            FuzzTarget::Wal => "wal",
            FuzzTarget::Snapshot => "snapshot",
            FuzzTarget::Replicate => "replicate",
            FuzzTarget::Srclint => "srclint",
        }
    }
}

/// Run `iters` mutated inputs against `target`. Never fails on rejected
/// inputs — only a panic (reported in the [`FuzzReport`]) is a defect.
pub fn run(target: FuzzTarget, iters: u64, seed: u64) -> FuzzReport {
    let mut rng = Rng::new(seed ^ 0xF0F0_F0F0_F0F0_F0F0);
    let seeds = seed_corpus(target);
    let mut report = FuzzReport {
        target,
        iters,
        accepted: 0,
        rejected: 0,
        panics: 0,
        first_panic: None,
    };
    // Panics inside catch_unwind would spam stderr through the default
    // hook; silence it for the duration and restore afterwards.
    let prev_hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    for i in 0..iters {
        let mut it = rng.fork();
        let base = &seeds[it.usize_range(0, seeds.len())];
        let input = mutate(&mut it, base);
        let outcome = catch_unwind(AssertUnwindSafe(|| drive(target, &input, &mut it)));
        match outcome {
            Ok(Verdict::Accepted) => report.accepted += 1,
            Ok(Verdict::Rejected) => report.rejected += 1,
            Err(panic) => {
                report.panics += 1;
                if report.first_panic.is_none() {
                    report.first_panic = Some((i, panic_message(&panic)));
                }
            }
        }
    }
    std::panic::set_hook(prev_hook);
    report
}

/// How one input fared (absent a panic).
enum Verdict {
    Accepted,
    Rejected,
}

/// Feed one mutated input to the target's production parser and check
/// the post-conditions a *successful* parse promises.
fn drive(target: FuzzTarget, input: &[u8], rng: &mut Rng) -> Verdict {
    match target {
        FuzzTarget::Wal => match wal::scan_bytes(input, Path::new("<fuzz>")) {
            Ok(scan) => {
                // A scan that "succeeds" must still be internally
                // consistent: the valid prefix cannot exceed the input.
                assert!(
                    scan.valid_len <= input.len() as u64,
                    "scan.valid_len {} > input {}",
                    scan.valid_len,
                    input.len()
                );
                if scan.torn() {
                    Verdict::Rejected
                } else {
                    Verdict::Accepted
                }
            }
            Err(_) => Verdict::Rejected,
        },
        FuzzTarget::Snapshot => match snapshot::decode(input, Path::new("<fuzz>")) {
            Ok(_) => Verdict::Accepted,
            Err(_) => Verdict::Rejected,
        },
        FuzzTarget::Http => {
            let framed = try_parse_request(input);
            // Whatever the frame parser said, also attack the JSON
            // protocol layer with the same mutated bytes — that is the
            // parser a framed body would reach next.
            let text = String::from_utf8_lossy(input);
            let mut ok = false;
            if let Ok(j) = Json::parse(&text) {
                // Every endpoint parser must hold the no-panic contract
                // for arbitrary *valid JSON* too.
                let which = rng.below(4);
                ok = match which {
                    0 => protocol::parse_select(&j).is_ok(),
                    1 => protocol::parse_select_batch(&j).is_ok(),
                    2 => protocol::parse_model(&j).is_ok(),
                    _ => protocol::parse_ingest(&j).is_ok(),
                };
            }
            match framed {
                Ok(Some(_)) => Verdict::Accepted,
                Ok(None) => Verdict::Rejected, // incomplete: server would keep reading
                Err(_) if ok => Verdict::Accepted,
                Err(_) => Verdict::Rejected,
            }
        }
        FuzzTarget::Replicate => {
            let text = String::from_utf8_lossy(input);
            if let Ok(j) = Json::parse(&text) {
                // Valid JSON attacks the wire parsers a replica trusts.
                let ok = if rng.below(2) == 0 {
                    replicate::parse_manifest(&j).is_ok()
                } else {
                    match replicate::parse_segment(&j) {
                        // A whole-segment fetch would reach the install
                        // validator next — drive that layer too.
                        Ok(chunk) if chunk.offset == 0
                            && chunk.data.len() as u64 == chunk.total_len =>
                        {
                            replicate::validate_segment_bytes(&chunk.name, &chunk.data).is_ok()
                        }
                        Ok(_) => true,
                        Err(_) => false,
                    }
                };
                if ok {
                    Verdict::Accepted
                } else {
                    Verdict::Rejected
                }
            } else {
                // Raw bytes attack the install-side segment validator
                // directly (the byte layer a verified fetch hands to the
                // installer).
                let name = if rng.below(2) == 0 { "snapshot.bin" } else { "wal-1.log" };
                match replicate::validate_segment_bytes(name, input) {
                    Ok(_) => Verdict::Accepted,
                    Err(_) => Verdict::Rejected,
                }
            }
        }
        FuzzTarget::Srclint => {
            // The lexer and rules must be total on arbitrary bytes: half-open
            // strings, truncated comments, stray punctuation. Scan under a
            // whole-file rule-1 path so every rule gets a chance to walk the
            // token stream; mutated source with findings counts as rejected.
            let text = String::from_utf8_lossy(input);
            let findings = crate::analysis::scan_source("rust/src/advisor/protocol.rs", &text);
            if findings.is_empty() {
                Verdict::Accepted
            } else {
                Verdict::Rejected
            }
        }
    }
}

/// Apply 1–4 random byte-level mutations to a copy of `base`.
///
/// The menu deliberately mirrors real corruption and real attacks:
/// truncation (torn writes), bit flips (media rot), length-field lies
/// (malicious frames), splices (misdirected writes / header smuggling),
/// duplicated tails (re-sent frames) and appended garbage (pipelined
/// trailing junk).
fn mutate(rng: &mut Rng, base: &[u8]) -> Vec<u8> {
    let mut bytes = base.to_vec();
    for _ in 0..rng.usize_range(1, 5) {
        if bytes.is_empty() {
            bytes.extend((0..rng.usize_range(1, 65)).map(|_| rng.below(256) as u8));
            continue;
        }
        let len = bytes.len();
        match rng.below(7) {
            // Truncate at an arbitrary offset.
            0 => {
                let at = rng.usize_range(0, len);
                bytes.truncate(at);
            }
            // Flip a single bit.
            1 => {
                let at = rng.usize_range(0, len);
                bytes[at] ^= 1u8 << (rng.below(8) as u32);
            }
            // Length-field lie: overwrite 4 bytes at a random offset
            // with a huge little-endian count.
            2 => {
                if len >= 4 {
                    let at = rng.usize_range(0, len - 3);
                    let lie: u32 = match rng.below(3) {
                        0 => u32::MAX,
                        1 => (64 << 20) + rng.below(1 << 20) as u32,
                        _ => rng.below(u32::MAX as u64 + 1) as u32,
                    };
                    bytes[at..at + 4].copy_from_slice(&lie.to_le_bytes());
                }
            }
            // Splice: replace a random range with random bytes.
            3 => {
                let start = rng.usize_range(0, len);
                let end = start + rng.below(((len - start).min(256) + 1) as u64) as usize;
                let fill: Vec<u8> =
                    (0..rng.usize_range(0, 65)).map(|_| rng.below(256) as u8).collect();
                bytes.splice(start..end, fill);
            }
            // Duplicate a tail chunk (a re-sent frame / doubled header).
            4 => {
                let at = rng.usize_range(0, len);
                let chunk: Vec<u8> = bytes[at..].iter().copied().take(256).collect();
                bytes.extend_from_slice(&chunk);
            }
            // Append garbage (pipelined junk after a valid message).
            5 => {
                bytes.extend((0..rng.usize_range(1, 129)).map(|_| rng.below(256) as u8));
            }
            // Byte swap across the input.
            _ => {
                let a = rng.usize_range(0, len);
                let b = rng.usize_range(0, len);
                bytes.swap(a, b);
            }
        }
    }
    // Bound the worst case so the harness itself cannot amplify.
    bytes.truncate(base.len() + 4096);
    bytes
}

/// Valid seed inputs per target — mutations start from bytes the parser
/// accepts, so the interesting near-valid corruption space gets hit.
fn seed_corpus(target: FuzzTarget) -> Vec<Vec<u8>> {
    match target {
        FuzzTarget::Http => vec![
            b"GET /v1/status HTTP/1.1\r\nHost: x\r\n\r\n".to_vec(),
            b"GET /metrics HTTP/1.1\r\nHost: x\r\nAccept: text/plain\r\n\r\n".to_vec(),
            b"POST /v1/select HTTP/1.1\r\nContent-Length: 49\r\n\r\n\
              {\"system\": {\"n\": 4, \"mttf_days\": 5}, \"app\": \"qr\"}"
                .to_vec(),
            b"POST /v1/select_batch HTTP/1.1\r\nContent-Length: 55\r\n\r\n\
              {\"items\": [{\"system\": {\"n\": 4}}, {\"system\": {\"n\": 8}}]}"
                .to_vec(),
            b"POST /v1/ingest HTTP/1.1\r\nConnection: keep-alive\r\nContent-Length: 77\r\n\r\n\
              {\"track\": \"t\", \"n_procs\": 2, \"events\": [{\"proc\": 0, \"fail\": 1, \"repair\": 2}]}"
                .to_vec(),
            // Two pipelined requests in one buffer.
            b"GET /v1/status HTTP/1.1\r\n\r\nPOST /v1/model HTTP/1.1\r\nContent-Length: 2\r\n\r\n{}"
                .to_vec(),
            // A scrape pipelined ahead of an API call — the mix a
            // monitoring agent sharing a connection would produce.
            b"GET /metrics HTTP/1.1\r\n\r\nGET /v1/status HTTP/1.1\r\nHost: x\r\n\r\n".to_vec(),
            // The explainability surfaces: query-string addressing puts
            // the `?key=`/`?request_id=` split-points in the corpus.
            b"GET /v1/explain?key=00000000deadbeef HTTP/1.1\r\nHost: x\r\n\r\n".to_vec(),
            b"GET /v1/debug/trace?request_id=42 HTTP/1.1\r\nAuthorization: Bearer t\r\n\r\n"
                .to_vec(),
            // Raw JSON bodies (the protocol layer sees these directly).
            br#"{"system": {"n": 6, "mttf_days": 8, "mttr_min": 40}, "search": {"refine_steps": 3}}"#
                .to_vec(),
            br#"{"track": "c1", "n_procs": 4, "events": [{"proc": 3, "fail": 10.5, "repair": 99}]}"#
                .to_vec(),
        ],
        FuzzTarget::Wal => vec![wal_image()],
        FuzzTarget::Snapshot => vec![snapshot_image()],
        FuzzTarget::Replicate => {
            let snap = snapshot_image();
            let walb = wal_image();
            // A valid manifest over one track: its snapshot plus two WAL
            // generations, entries built by the primary's own encoder.
            let segs = vec![
                replicate::segment_entry_json(snapshot::SNAPSHOT_FILE, &snap)
                    .expect("seed snapshot entry"),
                replicate::segment_entry_json("wal-3.log", &walb).expect("seed wal entry"),
                replicate::segment_entry_json("wal-4.log", &walb).expect("seed wal entry"),
            ];
            let mut track = Json::obj();
            track.set("encoded", Json::from("c1")).set("segments", Json::Arr(segs));
            let mut tracks = Json::obj();
            tracks.set("c1", track);
            let mut manifest = Json::obj();
            manifest
                .set("ok", Json::from(true))
                .set("chunk_bytes", Json::from(replicate::CHUNK_BYTES))
                .set("tracks", tracks);
            // A valid whole-segment fetch response.
            let seg_resp =
                replicate::segment_response_json("c1", "wal-3.log", 0, walb.len() as u64, &walb);
            vec![
                manifest.to_compact().into_bytes(),
                seg_resp.to_compact().into_bytes(),
                // Raw segment bytes for the install-side validator.
                walb,
                snap,
            ]
        }
        FuzzTarget::Srclint => vec![
            // A clean snippet: mutants of it mostly stay finding-free.
            b"fn parse(line: &str) -> Option<u32> {\n    let n = line.trim().parse::<u32>().ok()?;\n    Some(n)\n}\n"
                .to_vec(),
            // A violating snippet (panicky call + slice index under a
            // whole-file rule-1 path) so the rejected half of the space
            // is explored too.
            b"fn decode(v: &[u8]) -> u32 {\n    let head = v.first().unwrap();\n    u32::from(*head) + u32::from(v[1])\n}\n"
                .to_vec(),
        ],
    }
}

/// A valid WAL byte image containing every record kind.
fn wal_image() -> Vec<u8> {
    let mut bytes = WAL_MAGIC.to_vec();
    let records = [
        WalRecord::Create { n_procs: 4 },
        WalRecord::Outage { proc: 1, fail: 1_000.0, repair: 2_500.0 },
        WalRecord::Refit { lambda: 1.0 / 86_400.0, theta: 1.0 / 2_400.0 },
        WalRecord::Recommendation(Box::new(sample_spec())),
        WalRecord::Evict { cutoff: 3_000.0 },
        WalRecord::Outage { proc: 0, fail: 9_000.0, repair: 9_800.0 },
    ];
    for rec in &records {
        bytes.extend_from_slice(&encode_frame(rec));
    }
    bytes
}

/// A valid snapshot byte image with rates and a registered spec.
fn snapshot_image() -> Vec<u8> {
    let mut state = TrackState::new(4).expect("4 procs is valid");
    state.rates = Some((1.0 / 86_400.0, 1.0 / 2_400.0));
    state.specs.push(sample_spec());
    state.accepted = 7;
    state.merged = 1;
    snapshot::encode(3, 42, &state)
}

/// A fully-populated recommendation record — the deepest decoder the
/// WAL and snapshot share.
fn sample_spec() -> SpecRecord {
    let system = SystemParams::new(4, 1.0 / (5.0 * 86_400.0), 1.0 / 2_400.0);
    let app = AppProfile::qr(4);
    let policy = ReschedulingPolicy::greedy(4);
    let inputs = ModelInputs::new(system, &app, &policy).expect("sample inputs are valid");
    SpecRecord {
        identity: 0x1234_5678_9ABC_DEF0,
        key: 0x0FED_CBA9_8765_4321,
        rates_used: (system.lambda, system.theta),
        refresh: false,
        inputs,
        cfg: SearchConfig::default(),
    }
}

/// Best-effort text of a caught panic payload.
fn panic_message(panic: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = panic.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_corpora_are_valid_for_their_parsers() {
        // Unmutated seeds must parse cleanly — otherwise the fuzzer
        // never explores the near-valid space it exists for.
        let scan = wal::scan_bytes(&wal_image(), Path::new("<seed>")).unwrap();
        assert_eq!(scan.records.len(), 6);
        assert!(!scan.torn(), "seed WAL image has a torn tail: {:?}", scan.error);

        let snap = snapshot::decode(&snapshot_image(), Path::new("<seed>")).unwrap();
        assert_eq!((snap.gen, snap.covered), (3, 42));

        for seed in seed_corpus(FuzzTarget::Http).iter().take(9) {
            // The HTTP seeds (first nine) are complete frames.
            let parsed = try_parse_request(seed).expect("seed frame must parse");
            assert!(parsed.is_some(), "seed frame incomplete: {:?}", String::from_utf8_lossy(seed));
        }

        // The replicate seeds must satisfy the wire parsers unmutated.
        let rep = seed_corpus(FuzzTarget::Replicate);
        let manifest = Json::parse(&String::from_utf8(rep[0].clone()).unwrap()).unwrap();
        let parsed = replicate::parse_manifest(&manifest).expect("seed manifest must parse");
        assert_eq!(parsed.tracks.len(), 1);
        assert_eq!(parsed.tracks[0].segments.len(), 3);
        let seg = Json::parse(&String::from_utf8(rep[1].clone()).unwrap()).unwrap();
        let chunk = replicate::parse_segment(&seg).expect("seed segment must parse");
        assert_eq!(chunk.offset, 0);
        replicate::validate_segment_bytes(&chunk.name, &chunk.data)
            .expect("seed segment bytes must validate");

        // The srclint seeds: the first scans clean, the second violates.
        let lint = seed_corpus(FuzzTarget::Srclint);
        let path = "rust/src/advisor/protocol.rs";
        let clean = crate::analysis::scan_source(path, &String::from_utf8(lint[0].clone()).unwrap());
        assert!(clean.is_empty(), "clean srclint seed has findings: {clean:?}");
        let dirty = crate::analysis::scan_source(path, &String::from_utf8(lint[1].clone()).unwrap());
        assert!(!dirty.is_empty(), "violating srclint seed scanned clean");
    }

    #[test]
    fn http_seed_content_lengths_are_exact_or_pipelined() {
        // Each POST seed's Content-Length must cover exactly the bytes
        // present, so `Ok(Some)` consumed the whole (or prefix) frame.
        for seed in seed_corpus(FuzzTarget::Http) {
            if let Ok(Some((req, consumed))) = try_parse_request(&seed) {
                assert!(consumed <= seed.len());
                if req.method == "POST" && consumed == seed.len() {
                    assert!(!req.body.is_empty());
                }
            }
        }
    }

    #[test]
    fn fuzz_targets_survive_a_smoke_burst_deterministically() {
        for target in [
            FuzzTarget::Http,
            FuzzTarget::Wal,
            FuzzTarget::Snapshot,
            FuzzTarget::Replicate,
            FuzzTarget::Srclint,
        ] {
            let a = run(target, 300, 7);
            assert_eq!(a.panics, 0, "{}: {:?}", target.name(), a.first_panic);
            assert_eq!(a.iters, 300);
            assert_eq!(a.accepted + a.rejected, 300);
            // Replay determinism: same seed, same split.
            let b = run(target, 300, 7);
            assert_eq!((a.accepted, a.rejected), (b.accepted, b.rejected));
            // The mutation engine must leave some inputs parseable and
            // break others — both halves of the space get exercised.
            assert!(a.rejected > 0, "{}: nothing rejected", target.name());
        }
    }

    #[test]
    fn target_names_round_trip() {
        for name in ["http", "wal", "snapshot", "replicate", "srclint"] {
            assert_eq!(FuzzTarget::from_name(name).unwrap().name(), name);
        }
        assert!(FuzzTarget::from_name("tcp").is_err());
    }
}
