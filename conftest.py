"""Repo-root pytest shim: make `pytest python/tests/` work from the root
by putting the build-time python package (python/compile) on sys.path."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
