//! End-to-end driver (Figure 5 + headline metric): run the **full stack**
//! on a realistic workload — an 80-day QR execution on a 128-workstation
//! Condor-like pool.
//!
//! ```bash
//! cargo run --release --example condor_longrun
//! ```
//!
//! Pipeline exercised, all layers composing:
//!   1. synthesize a 100-day failure trace matched to the paper's
//!      condor/128 rates (λ = 1/6.36 d, θ = 1/54.8 min);
//!   2. estimate (λ̂, θ̂) from the trace history only;
//!   3. build `M^mall` through the AOT JAX/Pallas artifacts (PJRT) and
//!      search for I_model;
//!   4. simulate the 80-day execution at I_model with the paper's
//!      worst-case shared-network overheads C = R = 20 min;
//!   5. sweep the simulator for the oracle interval and report the
//!      paper's headline: model efficiency (>80%) and UWT as a fraction
//!      of failure-free throughput (~70% in Fig 5).

use malleable_ckpt::apps::AppProfile;
use malleable_ckpt::config::paper_system;
use malleable_ckpt::markov::ModelInputs;
use malleable_ckpt::metrics::sweep_grid;
use malleable_ckpt::policies::ReschedulingPolicy;
use malleable_ckpt::runtime::ComputeEngine;
use malleable_ckpt::search::{select_interval, SearchConfig};
use malleable_ckpt::simulator::{SimConfig, Simulator};
use malleable_ckpt::traces::stats::estimate_rates;
use malleable_ckpt::traces::synth::{generate, SynthSpec};
use malleable_ckpt::util::rng::Rng;
use malleable_ckpt::util::stats::fmt_duration;
use malleable_ckpt::config::SystemParams;

fn main() -> anyhow::Result<()> {
    let day = 86_400.0;
    let sys = paper_system("condor/128").unwrap();
    let mut rng = Rng::new(5);

    println!("1. generating 100-day condor/128 trace (λ=1/6.36 d, θ=1/54.8 min)...");
    let trace = generate(
        &SynthSpec::exponential(sys.n, sys.lambda, sys.theta, 100.0 * day),
        &mut rng,
    );
    let total_failures: usize = (0..sys.n).map(|p| trace.failure_count(p)).sum();
    println!("   {} processors, {} failure events", sys.n, total_failures);

    let start = 15.0 * day;
    let duration = 80.0 * day;

    println!("2. estimating rates from history before day 15...");
    let (lam_hat, theta_hat) = estimate_rates(&trace, start)?;
    println!(
        "   λ̂ = 1/({:.2} d), θ̂ = 1/({:.1} min)",
        1.0 / (lam_hat * day),
        1.0 / (theta_hat * 60.0)
    );

    println!("3. building M^mall and searching for I_model...");
    let engine = ComputeEngine::auto();
    println!("   engine: {}", engine.name());
    let app = AppProfile::qr(sys.n);
    let policy = ReschedulingPolicy::greedy(sys.n);
    let est_sys = SystemParams::new(sys.n, lam_hat, theta_hat);
    let inputs = ModelInputs::new(est_sys, &app, &policy)?;
    let search = select_interval(
        &inputs,
        &engine,
        &SearchConfig { refine_steps: 3, ..Default::default() },
    )?;
    println!(
        "   I_model = {} (model UWT {:.3}; paper used 1.53 h here)",
        fmt_duration(search.interval),
        search.uwt
    );

    println!("4. simulating 80 days at I_model with C = R = 20 min...");
    let mut cfg = SimConfig::new(start, duration, search.interval);
    cfg.ckpt_override = Some(20.0 * 60.0);
    cfg.rec_override = Some(20.0 * 60.0);
    cfg.record_timeline = true;
    let sim = Simulator::new(&trace, &app, &policy);
    let res = sim.run(&cfg)?;

    let max_rate = (1..=sys.n).map(|a| app.work_per_sec(a)).fold(0.0, f64::max);
    println!(
        "   UWT = {:.2} iterations/s = {:.0}% of failure-free max {:.2} (paper Fig 5: ~70%)",
        res.uwt,
        100.0 * res.uwt / max_rate,
        max_rate
    );
    println!(
        "   {} failures hit the app, {} checkpoints, {:.1} h waiting, {:.1} h redistributing",
        res.failures,
        res.checkpoints,
        res.wait_seconds / 3_600.0,
        res.recovery_seconds / 3_600.0
    );

    // Processors-in-use timeline, ~weekly buckets (Fig 5's step plot).
    println!("\n   day  procs in use");
    for week in 0..12 {
        let t0 = start + week as f64 * 7.0 * day;
        if t0 > start + duration {
            break;
        }
        let a = res
            .timeline
            .iter()
            .rev()
            .find(|&&(ts, _)| ts <= t0)
            .map(|&(_, a)| a)
            .unwrap_or(0);
        println!("   {:>4}  {:>3}  {}", week * 7, a, "*".repeat(a / 4));
    }

    println!("\n5. simulator oracle sweep (UW_highest / I_sim)...");
    let mut best = (0.0f64, 0.0f64);
    for iv in sweep_grid(300.0, 2.0 * day, 16) {
        let mut c = cfg.clone();
        c.interval = iv;
        c.record_timeline = false;
        let r = sim.run(&c)?;
        if r.useful_work > best.1 {
            best = (iv, r.useful_work);
        }
    }
    let efficiency = 100.0 * res.useful_work / best.1;
    println!(
        "   I_sim = {}, UW_highest = {:.3e}, UW(I_model) = {:.3e}",
        fmt_duration(best.0),
        best.1,
        res.useful_work
    );
    println!("\n=> model efficiency = {efficiency:.1}% (paper headline: >80%)");
    assert!(efficiency > 60.0, "efficiency collapsed — investigate");
    Ok(())
}
