//! Rescheduling-policy study (Table IV scenario): Greedy vs
//! Performance-Based vs Availability-Based on the same system/application.
//!
//! ```bash
//! cargo run --release --example policy_study
//! ```
//!
//! Reproduces the paper's §VI-D finding: AB runs on fewer processors with
//! lower aggregate failure rates, selects larger checkpointing intervals,
//! and accumulates the most useful work; Greedy and PB are close to each
//! other because QR is highly scalable.

use malleable_ckpt::apps::AppProfile;
use malleable_ckpt::config::paper_system;
use malleable_ckpt::metrics::evaluate_segment;
use malleable_ckpt::policies::ReschedulingPolicy;
use malleable_ckpt::runtime::ComputeEngine;
use malleable_ckpt::search::SearchConfig;
use malleable_ckpt::traces::synth::{generate, SynthSpec};
use malleable_ckpt::util::rng::Rng;
use malleable_ckpt::util::stats::fmt_duration;

fn main() -> anyhow::Result<()> {
    let day = 86_400.0;
    // The paper's Table IV uses system-1/128 (LANL batch system).
    let sys = paper_system("system-1/128").unwrap();
    // Scale down for an example that runs in seconds; the bench harness
    // runs the full 128-processor version.
    let n = 32usize;
    let sys = malleable_ckpt::config::SystemParams::new(n, sys.lambda * 8.0, sys.theta);

    let mut rng = Rng::new(17);
    let trace = generate(&SynthSpec::exponential(n, sys.lambda, sys.theta, 120.0 * day), &mut rng);
    let app = AppProfile::qr(n);
    let engine = ComputeEngine::auto();
    println!("engine: {} | system: N={n}, MTTF {:.1} d/node\n", engine.name(), 1.0 / (sys.lambda * day));

    let policies = vec![
        ReschedulingPolicy::greedy(n),
        ReschedulingPolicy::performance_based(app.work_vector())?,
        ReschedulingPolicy::availability_based(&trace, 50, &mut rng)?,
    ];

    println!(
        "{:<8} {:>10} {:>12} {:>12} {:>8} {:>12}",
        "policy", "procs@N", "I_model", "UW(I_model)", "eff %", "image size"
    );
    for policy in &policies {
        let eval = evaluate_segment(
            &trace,
            &app,
            policy,
            &engine,
            30.0 * day,
            40.0 * day,
            &SearchConfig { refine_steps: 2, ..Default::default() },
            Some((sys.lambda, sys.theta)),
        )?;
        println!(
            "{:<8} {:>10} {:>12} {:>12.3e} {:>8.1} {:>12}",
            policy.name,
            policy.procs_for(n),
            fmt_duration(eval.i_model),
            eval.uw_model,
            eval.efficiency,
            policy.image().len()
        );
    }

    println!("\npaper Table IV shape: AB picks far fewer processors and a much larger I;");
    println!("Greedy/PB are comparable because QR scales well. (On homogeneous traces");
    println!("AB's useful-work advantage disappears — it needs node heterogeneity; see");
    println!("`malleable-ckpt experiment hetero` for that mechanism isolated.)");
    Ok(())
}
