//! Quickstart: select a checkpointing interval for a malleable application.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Builds the paper's `M^mall` model for a 64-processor system, probes
//! checkpointing intervals, and prints the UWT-optimal selection along
//! with the probed curve.

use malleable_ckpt::apps::AppProfile;
use malleable_ckpt::config::SystemParams;
use malleable_ckpt::markov::ModelInputs;
use malleable_ckpt::policies::ReschedulingPolicy;
use malleable_ckpt::runtime::ComputeEngine;
use malleable_ckpt::search::{select_interval, SearchConfig};
use malleable_ckpt::util::stats::fmt_duration;

fn main() -> anyhow::Result<()> {
    // A 64-processor system where each node fails about every 6 days and
    // takes ~50 minutes to repair (Condor-pool-like volatility).
    let system = SystemParams::from_mttf_mttr(64, 6.42, 47.13);

    // The ScaLAPACK QR solver profile (workinunittime / C / R calibrated
    // to the paper's Table I and Figure 4).
    let app = AppProfile::qr(system.n);

    // Greedy rescheduling: after every failure, continue on all
    // functional processors.
    let policy = ReschedulingPolicy::greedy(system.n);

    // AOT JAX/Pallas artifacts through PJRT when artifacts/ exists,
    // otherwise the native mirror.
    let engine = ComputeEngine::auto();
    println!("compute engine: {}\n", engine.name());

    let inputs = ModelInputs::new(system, &app, &policy)?;
    let result = select_interval(&inputs, &engine, &SearchConfig::default())?;

    println!("probed UWT(I) curve:");
    for (interval, uwt) in &result.probes {
        let bar = "#".repeat((uwt / result.uwt * 40.0) as usize);
        println!("  {:>10}  {uwt:7.4}  {bar}", fmt_duration(*interval));
    }
    println!(
        "\nI_model = {} (UWT {:.4}, {} model builds)",
        fmt_duration(result.interval),
        result.uwt,
        result.evaluations
    );
    println!(
        "paper reference point (Table II, 64 procs, system-1): I_model ≈ 2.81 h"
    );
    Ok(())
}
