//! Volatile (Condor) vs dedicated batch systems, and malleable vs moldable
//! execution — the §VI-D usefulness argument of the paper.
//!
//! ```bash
//! cargo run --release --example volatile_vs_batch
//! ```
//!
//! Two comparisons on the same hardware scale:
//!   a) the model's chosen interval on a batch system vs a Condor pool
//!      (paper: Condor intervals are much shorter);
//!   b) malleable vs fixed-size moldable execution on the Condor pool
//!      (paper: moldable apps stall on volatile pools; malleable ones
//!      retain most of the failure-free throughput).

use malleable_ckpt::apps::AppProfile;
use malleable_ckpt::baselines::daly;
use malleable_ckpt::baselines::moldable::simulate_moldable;
use malleable_ckpt::config::SystemParams;
use malleable_ckpt::markov::ModelInputs;
use malleable_ckpt::policies::ReschedulingPolicy;
use malleable_ckpt::runtime::ComputeEngine;
use malleable_ckpt::search::{select_interval, SearchConfig};
use malleable_ckpt::simulator::{SimConfig, Simulator};
use malleable_ckpt::traces::synth::{generate, SynthSpec};
use malleable_ckpt::util::rng::Rng;
use malleable_ckpt::util::stats::fmt_duration;

fn main() -> anyhow::Result<()> {
    let day = 86_400.0;
    let n = 24usize;
    let engine = ComputeEngine::auto();
    let app = AppProfile::qr(n);
    let policy = ReschedulingPolicy::greedy(n);
    println!("engine: {}\n", engine.name());

    // (a) Interval selection across environments.
    println!("(a) I_model across environments (QR, greedy, N={n}):");
    println!("{:<22} {:>10} {:>12} {:>12}", "system", "MTTF/node", "I_model", "I_daly");
    for (name, mttf_days, mttr_min) in [
        ("batch (LANL-like)", 104.61, 56.03),
        ("volatile (Condor)", 6.36, 54.85),
        ("hyper-volatile", 0.8, 54.85),
    ] {
        let sys = SystemParams::from_mttf_mttr(n, mttf_days, mttr_min);
        let inputs = ModelInputs::new(sys, &app, &policy)?;
        let res = select_interval(
            &inputs,
            &engine,
            &SearchConfig { refine_steps: 2, ..Default::default() },
        )?;
        // Daly baseline with aggregate MTBF of all N processors.
        let daly_i = daly::daly_interval(app.checkpoint_cost(n), 1.0 / (n as f64 * sys.lambda));
        println!(
            "{:<22} {:>8.1} d {:>12} {:>12}",
            name,
            mttf_days,
            fmt_duration(res.interval),
            fmt_duration(daly_i)
        );
    }

    // (b) Malleable vs moldable on the volatile pool.
    println!("\n(b) malleable vs moldable on the Condor-like pool (30 days, QR):");
    let sys = SystemParams::from_mttf_mttr(n, 6.36, 54.85);
    let mut rng = Rng::new(23);
    let trace = generate(&SynthSpec::exponential(n, sys.lambda, sys.theta, 45.0 * day), &mut rng);
    let interval = 1.53 * 3_600.0;
    let (start, dur) = (5.0 * day, 30.0 * day);

    let cfg = SimConfig::new(start, dur, interval);
    let mal = Simulator::new(&trace, &app, &policy).run(&cfg)?;
    println!("{:<16} {:>12} {:>10} {:>10}", "mode", "UW", "UWT", "wait h");
    println!(
        "{:<16} {:>12.3e} {:>10.3} {:>10.1}",
        "malleable",
        mal.useful_work,
        mal.uwt,
        mal.wait_seconds / 3_600.0
    );
    for a in [n, 3 * n / 4, n / 2] {
        let m = simulate_moldable(&trace, &app, a, &cfg)?;
        println!(
            "{:<16} {:>12.3e} {:>10.3} {:>10.1}",
            format!("moldable-{a}"),
            m.useful_work,
            m.uwt,
            m.wait_seconds / 3_600.0
        );
    }
    println!("\npaper §VI-D: volatile pools are unusable for moldable runs but");
    println!("provide near-failure-free throughput to malleable ones.");
    Ok(())
}
