# Tier-1 verification and perf tracking for the malleable-ckpt repo.

.PHONY: verify build test lint fmt srclint serve-smoke fuzz-smoke bench-smoke bench clean

# Tier-1: release build + full test suite + the repo-invariant static
# analyzer (see ROADMAP.md).
verify: build test srclint

build:
	cargo build --release

test:
	cargo test -q

# Style gate, mirrored by the CI `lint` job (blocking since PR 3).
lint:
	cargo fmt --all -- --check
	cargo clippy --all-targets -- -D warnings

# Apply rustfmt in place (the fix-up for a failing `make lint`).
fmt:
	cargo fmt --all

# Repo-invariant static analyzer (DESIGN.md §16), mirrored by the CI
# `srclint` job: no-panic-paths, total-cmp-only, lock-order,
# typed-errors, route-coverage. Any finding fails the run.
srclint: build
	./target/release/malleable-ckpt srclint rust/src

# Boot the advisor daemon from the release binary and exercise it over
# HTTP against the offline oracle (mirrors the CI `serve-smoke` job).
serve-smoke: build
	bash scripts/serve_smoke.sh

# Deterministic robustness fuzzing (DESIGN.md §12), mirroring the CI
# `fuzz-smoke` job: any panic in a parser or reader fails the run.
fuzz-smoke: build
	./target/release/malleable-ckpt fuzz http --iters 5000 --seed 1
	./target/release/malleable-ckpt fuzz wal --iters 5000 --seed 2
	./target/release/malleable-ckpt fuzz snapshot --iters 5000 --seed 3
	./target/release/malleable-ckpt fuzz replicate --iters 5000 --seed 4
	./target/release/malleable-ckpt fuzz srclint --iters 5000 --seed 5

# Short smoke bench: regenerates BENCH_perf.json at the repo root with the
# reduced size grid, so perf regressions show up in every PR.
bench-smoke:
	cargo bench --bench perf -- --smoke

# Full perf sweep, paper scale (N = 512 included). Slow.
bench:
	cargo bench --bench perf

clean:
	cargo clean
