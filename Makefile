# Tier-1 verification and perf tracking for the malleable-ckpt repo.

.PHONY: verify build test lint fmt serve-smoke bench-smoke bench clean

# Tier-1: release build + full test suite (see ROADMAP.md).
verify: build test

build:
	cargo build --release

test:
	cargo test -q

# Style gate, mirrored by the CI `lint` job (blocking since PR 3).
lint:
	cargo fmt --all -- --check
	cargo clippy --all-targets -- -D warnings

# Apply rustfmt in place (the fix-up for a failing `make lint`).
fmt:
	cargo fmt --all

# Boot the advisor daemon from the release binary and exercise it over
# HTTP against the offline oracle (mirrors the CI `serve-smoke` job).
serve-smoke: build
	bash scripts/serve_smoke.sh

# Short smoke bench: regenerates BENCH_perf.json at the repo root with the
# reduced size grid, so perf regressions show up in every PR.
bench-smoke:
	cargo bench --bench perf -- --smoke

# Full perf sweep, paper scale (N = 512 included). Slow.
bench:
	cargo bench --bench perf

clean:
	cargo clean
