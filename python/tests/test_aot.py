"""AOT lowering: HLO text artifacts parse, have the expected entry layout,
and contain no custom-calls the CPU PJRT client cannot execute."""

import json
import re

import pytest

from compile import aot


@pytest.fixture(scope="module")
def hlo_small():
    return aot.lower_chain_probs(8)


def test_entry_layout(hlo_small):
    assert "entry_computation_layout" in hlo_small
    assert "f64[8,8]" in hlo_small
    # 3 matrix outputs as a tuple
    assert re.search(r"->\s*\(f64\[8,8\]\{1,0\}, f64\[8,8\]\{1,0\}, f64\[8,8\]\{1,0\}\)", hlo_small)


def test_no_custom_calls(hlo_small):
    """LAPACK/Mosaic custom-calls would be unexecutable on the rust CPU
    client; the whole point of the resolvent/Taylor formulation is their
    absence."""
    assert "custom-call" not in hlo_small


def test_dynamic_squaring_loop_present(hlo_small):
    """The data-dependent squaring count must lower to a `while`, not an
    unrolled (shape-specialised) loop."""
    assert "while(" in hlo_small


def test_f64_only(hlo_small):
    """Probability math must not silently drop to f32."""
    assert "f32[8,8]" not in hlo_small


def test_expm_artifact():
    text = aot.lower_expm(8)
    assert "custom-call" not in text
    assert re.search(r"->\s*\(f64\[8,8\]\{1,0\}\)", text)


def test_manifest_roundtrip(tmp_path):
    import os
    import subprocess
    import sys

    # `compile` is importable from the python/ directory (tests may be
    # launched from the repo root via the root conftest shim).
    pkg_dir = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--buckets", "8"],
        check=True,
        cwd=pkg_dir,
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["dtype"] == "f64"
    assert manifest["chain_probs"]["8"] == "chain_probs_8.hlo.txt"
    assert (out / "chain_probs_8.hlo.txt").exists()
    assert (out / "expm_8.hlo.txt").exists()
