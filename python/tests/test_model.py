"""Layer-2 chain_probs vs pure-jnp oracle; padding and stochasticity invariants."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from .conftest import bd_generator


def _params(s_max, a, mttf_days, mttr_min, delta):
    lam = 1.0 / (mttf_days * 86400.0)
    theta = 1.0 / (mttr_min * 60.0)
    return lam, theta, a * lam, delta


@settings(max_examples=20, deadline=None)
@given(
    s_max=st.integers(0, 40),
    a=st.integers(1, 256),
    mttf_days=st.floats(1.0, 150.0),
    mttr_min=st.floats(10.0, 200.0),
    delta=st.floats(600.0, 2.0e5),
)
def test_matches_oracle(s_max, a, mttf_days, mttr_min, delta):
    lam, theta, a_lam, delta = _params(s_max, a, mttf_days, mttr_min, delta)
    r = jnp.asarray(bd_generator(s_max, lam, theta))
    got = model.chain_probs(r, jnp.float64(a_lam), jnp.float64(delta))
    want = ref.chain_probs(r, jnp.float64(a_lam), jnp.float64(delta))
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-7, atol=1e-10)


@settings(max_examples=15, deadline=None)
@given(
    s_max=st.integers(0, 20),
    pad_to=st.sampled_from([32, 64]),
    a=st.integers(1, 64),
    delta=st.floats(600.0, 1.0e5),
)
def test_padding_inert(s_max, pad_to, a, delta):
    """Zero-padded generator rows must yield an exact identity pad block and
    leave the live block equal to the unpadded computation."""
    lam, theta = 3e-6, 4e-4
    a_lam = a * lam
    r_pad = jnp.asarray(bd_generator(s_max, lam, theta, n=pad_to))
    r_live = jnp.asarray(bd_generator(s_max, lam, theta))
    got_pad = model.chain_probs(r_pad, jnp.float64(a_lam), jnp.float64(delta))
    got_live = model.chain_probs(r_live, jnp.float64(a_lam), jnp.float64(delta))
    m = s_max + 1
    for gp, gl in zip(got_pad, got_live):
        gp = np.asarray(gp)
        np.testing.assert_allclose(gp[:m, :m], np.asarray(gl), rtol=1e-9, atol=1e-12)
        np.testing.assert_allclose(gp[m:, m:], np.eye(pad_to - m), atol=1e-10)
        np.testing.assert_allclose(gp[:m, m:], 0.0, atol=1e-10)
        np.testing.assert_allclose(gp[m:, :m], 0.0, atol=1e-10)


@settings(max_examples=15, deadline=None)
@given(
    s_max=st.integers(0, 48),
    a=st.integers(1, 512),
    mttf_days=st.floats(0.5, 200.0),
    mttr_min=st.floats(5.0, 500.0),
    delta=st.floats(300.0, 5.0e5),
)
def test_outputs_row_stochastic(s_max, a, mttf_days, mttr_min, delta):
    lam, theta, a_lam, delta = _params(s_max, a, mttf_days, mttr_min, delta)
    r = jnp.asarray(bd_generator(s_max, lam, theta))
    for q in model.chain_probs(r, jnp.float64(a_lam), jnp.float64(delta)):
        q = np.asarray(q)
        np.testing.assert_allclose(q.sum(axis=1), np.ones(s_max + 1), rtol=1e-8)
        assert (q > -1e-10).all()


def test_single_state_chain():
    """S = 0 (no spares): all matrices are the 1x1 identity."""
    r = jnp.zeros((1, 1), dtype=jnp.float64)
    for q in model.chain_probs(r, jnp.float64(1e-4), jnp.float64(3600.0)):
        np.testing.assert_allclose(np.asarray(q), [[1.0]], atol=1e-12)


def test_tiny_delta_qrec_stable():
    """delta -> 0: conditioning denominator 1-e^{-a lam delta} underflows
    without expm1; q_rec must stay row-stochastic."""
    r = jnp.asarray(bd_generator(8, 2e-6, 4e-4))
    _, _, q_rec = model.chain_probs(r, jnp.float64(1e-5), jnp.float64(1e-3))
    q = np.asarray(q_rec)
    np.testing.assert_allclose(q.sum(axis=1), np.ones(9), rtol=1e-6)
    # In the delta->0 limit no spare transitions can happen: q_rec -> I.
    np.testing.assert_allclose(q, np.eye(9), atol=1e-5)


def test_huge_delta_qrec_approaches_qup():
    """delta -> inf: conditioning on tau < delta vanishes, q_rec -> q_up."""
    r = jnp.asarray(bd_generator(8, 2e-6, 4e-4))
    q_delta, q_up, q_rec = model.chain_probs(r, jnp.float64(1e-4), jnp.float64(1e9))
    np.testing.assert_allclose(np.asarray(q_rec), np.asarray(q_up), rtol=1e-6, atol=1e-9)
    del q_delta
