"""Layer-1 Pallas matmul kernel vs pure-jnp oracle (hypothesis sweeps)."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul_pallas, ref

SEED = st.integers(0, 2**31 - 1)


def _rand(rng, shape, dtype):
    a = rng.standard_normal(shape)
    return jnp.asarray(a, dtype=dtype)


@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([1, 3, 8, 17, 64, 128]),
    k=st.sampled_from([1, 5, 8, 64, 96]),
    n=st.sampled_from([1, 2, 8, 64, 128]),
    seed=SEED,
)
def test_matches_oracle_shapes(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (m, k), jnp.float64)
    y = _rand(rng, (k, n), jnp.float64)
    got = matmul_pallas.matmul(x, y)
    want = ref.matmul(x, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)


@settings(max_examples=10, deadline=None)
@given(dtype=st.sampled_from(["float32", "float64"]), seed=SEED)
def test_dtypes(dtype, seed):
    rng = np.random.default_rng(seed)
    dt = jnp.dtype(dtype)
    x = _rand(rng, (32, 32), dt)
    y = _rand(rng, (32, 32), dt)
    got = matmul_pallas.matmul(x, y)
    assert got.dtype == dt
    tol = 1e-4 if dtype == "float32" else 1e-12
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.matmul(x, y)), rtol=tol, atol=tol
    )


@settings(max_examples=8, deadline=None)
@given(block=st.sampled_from([16, 32, 64, 128]), seed=SEED)
def test_block_sizes_equivalent(block, seed):
    """Tiling must not change the result (beyond fp addition order)."""
    rng = np.random.default_rng(seed)
    x = _rand(rng, (128, 128), jnp.float64)
    y = _rand(rng, (128, 128), jnp.float64)
    got = matmul_pallas.matmul(x, y, block=block)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.matmul(x, y)), rtol=1e-11, atol=1e-11
    )


def test_identity():
    eye = jnp.eye(64, dtype=jnp.float64)
    rng = np.random.default_rng(7)
    x = _rand(rng, (64, 64), jnp.float64)
    np.testing.assert_allclose(np.asarray(matmul_pallas.matmul(x, eye)), np.asarray(x))
    np.testing.assert_allclose(np.asarray(matmul_pallas.matmul(eye, x)), np.asarray(x))


def test_zero():
    z = jnp.zeros((16, 16), dtype=jnp.float64)
    rng = np.random.default_rng(8)
    x = _rand(rng, (16, 16), jnp.float64)
    assert np.all(np.asarray(matmul_pallas.matmul(x, z)) == 0.0)


def test_contraction_mismatch_raises():
    x = jnp.zeros((4, 5), dtype=jnp.float64)
    y = jnp.zeros((6, 4), dtype=jnp.float64)
    with pytest.raises(ValueError, match="contraction mismatch"):
        matmul_pallas.matmul(x, y)


def test_associativity_with_oracle_chain():
    """(x@y)@z via kernel equals oracle chain within fp tolerance."""
    rng = np.random.default_rng(9)
    x = _rand(rng, (64, 64), jnp.float64)
    y = _rand(rng, (64, 64), jnp.float64)
    z = _rand(rng, (64, 64), jnp.float64)
    got = matmul_pallas.matmul(matmul_pallas.matmul(x, y), z)
    want = ref.matmul(ref.matmul(x, y), z)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-10, atol=1e-10)
