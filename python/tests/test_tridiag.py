"""Thomas tridiagonal solve vs dense jnp.linalg.solve oracle."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, tridiag
from .conftest import bd_generator


def _dd_system(rng, n, m):
    """Random strictly diagonally dominant tridiagonal system."""
    dl = rng.standard_normal(n)
    du = rng.standard_normal(n)
    dl[0] = 0.0
    du[-1] = 0.0
    dd = np.abs(rng.standard_normal(n)) + np.abs(dl) + np.abs(du) + 0.5
    dd *= np.where(rng.random(n) < 0.5, -1.0, 1.0)  # sign-indefinite diagonal
    b = rng.standard_normal((n, m))
    return tuple(jnp.asarray(v) for v in (dl, dd, du, b))


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([2, 3, 5, 16, 64, 200]),
    m=st.sampled_from([1, 2, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_oracle(n, m, seed):
    rng = np.random.default_rng(seed)
    dl, dd, du, b = _dd_system(rng, n, m)
    got = tridiag.solve(dl, dd, du, b)
    want = ref.tridiag_solve(dl, dd, du, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-9, atol=1e-10)


@settings(max_examples=15, deadline=None)
@given(
    s_max=st.integers(1, 64),
    a_lambda=st.floats(1e-7, 1e-2),
    seed=st.integers(0, 2**31 - 1),
)
def test_resolvent_of_generator(s_max, a_lambda, seed):
    """The exact system the model solves: (a*lam*I - R) X = I."""
    rng = np.random.default_rng(seed)
    lam = 10.0 ** rng.uniform(-7, -4)
    theta = 10.0 ** rng.uniform(-5, -2)
    r = bd_generator(s_max, lam, theta)
    n = s_max + 1
    mneg = jnp.asarray(-r)
    dl, dd, du = tridiag.bands_from_dense(mneg)
    dd = dd + a_lambda
    x = tridiag.solve(dl, dd, du, jnp.eye(n, dtype=jnp.float64))
    m = a_lambda * np.eye(n) - r
    np.testing.assert_allclose(m @ np.asarray(x), np.eye(n), atol=1e-9)
    # a*lam * resolvent is row-stochastic (it's Q^Up).
    np.testing.assert_allclose((a_lambda * np.asarray(x)).sum(axis=1), np.ones(n), rtol=1e-9)


def test_residual_property():
    """T @ solve(T, b) == b for assembled dense T."""
    rng = np.random.default_rng(42)
    n = 50
    dl, dd, du, b = _dd_system(rng, n, 7)
    x = np.asarray(tridiag.solve(dl, dd, du, b))
    t = np.diag(np.asarray(dd))
    t[np.arange(1, n), np.arange(n - 1)] = np.asarray(dl)[1:]
    t[np.arange(n - 1), np.arange(1, n)] = np.asarray(du)[: n - 1]
    np.testing.assert_allclose(t @ x, np.asarray(b), atol=1e-9)


def test_diagonal_only():
    dd = jnp.asarray([2.0, -4.0, 8.0])
    z = jnp.zeros(3, dtype=jnp.float64)
    b = jnp.asarray([[2.0], [8.0], [4.0]])
    x = tridiag.solve(z, dd, z, b)
    np.testing.assert_allclose(np.asarray(x)[:, 0], [1.0, -2.0, 0.5], atol=1e-14)


def test_bands_from_dense_roundtrip():
    rng = np.random.default_rng(3)
    n = 10
    dl, dd, du, _ = _dd_system(rng, n, 1)
    t = np.diag(np.asarray(dd))
    t[np.arange(1, n), np.arange(n - 1)] = np.asarray(dl)[1:]
    t[np.arange(n - 1), np.arange(1, n)] = np.asarray(du)[: n - 1]
    gl, gd, gu = tridiag.bands_from_dense(jnp.asarray(t))
    np.testing.assert_allclose(np.asarray(gd), np.asarray(dd))
    np.testing.assert_allclose(np.asarray(gl)[1:], np.asarray(dl)[1:])
    np.testing.assert_allclose(np.asarray(gu)[: n - 1], np.asarray(du)[: n - 1])
