"""Shared pytest fixtures/helpers for the compile-path test suite."""

import numpy as np
import jax

jax.config.update("jax_enable_x64", True)


def bd_generator(s_max: int, lam: float, theta: float, n: int | None = None):
    """Dense birth-death CTMC generator over spare counts 0..s_max (Eq. 1).

    State ``s`` = number of functional spares; failure of one of ``s`` spares
    at rate ``s * lam``, repair of one of ``s_max - s`` broken spares at rate
    ``(s_max - s) * theta``. Optionally zero-padded to ``n`` rows, matching
    what the rust runtime ships to the AOT artifact.
    """
    m = s_max + 1
    n = n or m
    r = np.zeros((n, n))
    for s in range(m):
        if s > 0:
            r[s, s - 1] = s * lam
        if s < m - 1:
            r[s, s + 1] = (s_max - s) * theta
        r[s, s] = -(r[s].sum() - r[s, s])
    return r
