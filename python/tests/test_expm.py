"""Scaling-and-squaring expm kernel vs jax.scipy Pade oracle."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import expm, ref
from .conftest import bd_generator


@settings(max_examples=20, deadline=None)
@given(
    s_max=st.integers(1, 48),
    mttf_days=st.floats(0.5, 200.0),
    mttr_min=st.floats(5.0, 300.0),
    delta=st.floats(300.0, 3.0e5),
    seed=st.integers(0, 2**31 - 1),
)
def test_bd_generator_matches_oracle(s_max, mttf_days, mttr_min, delta, seed):
    """Exponentials of the actual model generators across the paper's
    lambda/theta/delta ranges (LANL batch to Condor volatility)."""
    lam = 1.0 / (mttf_days * 86400.0)
    theta = 1.0 / (mttr_min * 60.0)
    r = jnp.asarray(bd_generator(s_max, lam, theta)) * delta
    got = expm.expm(r)
    want = ref.expm(r)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-8, atol=1e-11)
    # A CTMC transition matrix: row-stochastic, non-negative.
    g = np.asarray(got)
    np.testing.assert_allclose(g.sum(axis=1), np.ones(s_max + 1), rtol=1e-9)
    assert (g > -1e-12).all()


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([2, 5, 8, 16, 33]), scale=st.floats(1e-3, 50.0), seed=st.integers(0, 2**31 - 1))
def test_random_dense_matches_oracle(n, scale, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((n, n)) * scale / n)
    got = expm.expm(a)
    want = ref.expm(a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-7, atol=1e-9)


def test_zero_matrix_is_identity():
    z = jnp.zeros((16, 16), dtype=jnp.float64)
    np.testing.assert_allclose(np.asarray(expm.expm(z)), np.eye(16), atol=1e-15)


def test_diagonal_matrix():
    d = jnp.diag(jnp.asarray([-3.0, -1.0, 0.0, 2.0]))
    got = np.asarray(expm.expm(d))
    np.testing.assert_allclose(np.diag(got), np.exp([-3.0, -1.0, 0.0, 2.0]), rtol=1e-12)
    assert np.allclose(got - np.diag(np.diag(got)), 0.0, atol=1e-14)


def test_nilpotent():
    """exp of strictly upper triangular 2x2 has closed form."""
    a = jnp.asarray([[0.0, 5.0], [0.0, 0.0]])
    np.testing.assert_allclose(
        np.asarray(expm.expm(a)), np.array([[1.0, 5.0], [0.0, 1.0]]), atol=1e-14
    )


def test_semigroup_property():
    """expm(A) @ expm(A) == expm(2A) -- exercised via different squaring counts."""
    r = jnp.asarray(bd_generator(12, 2e-6, 4e-4)) * 5.0e4
    e1 = np.asarray(expm.expm(r))
    e2 = np.asarray(expm.expm(2.0 * r))
    np.testing.assert_allclose(e1 @ e1, e2, rtol=1e-8, atol=1e-11)


def test_large_norm_many_squarings():
    """||A|| ~ 1e4: the dynamic while-loop must take ~16 squarings."""
    r = jnp.asarray(bd_generator(63, 5e-6, 3.5e-4)) * 5.0e5
    got = np.asarray(expm.expm(r))
    # Long-horizon CTMC: every row approaches the stationary distribution.
    np.testing.assert_allclose(got.sum(axis=1), np.ones(64), rtol=1e-8)
    spread = got.max(axis=0) - got.min(axis=0)
    assert spread.max() < 1e-6, "rows should have mixed to stationarity"
