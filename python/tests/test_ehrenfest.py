"""Closed-form Ehrenfest transition matrix vs the generic expm oracle,
and the fast chain_probs path vs the paper-faithful one."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ehrenfest, ref
from .conftest import bd_generator


@settings(max_examples=20, deadline=None)
@given(
    s_max=st.integers(0, 40),
    mttf_days=st.floats(0.5, 150.0),
    mttr_min=st.floats(5.0, 300.0),
    delta=st.floats(1.0, 3.0e5),
)
def test_matches_generic_expm(s_max, mttf_days, mttr_min, delta):
    lam = 1.0 / (mttf_days * 86_400.0)
    theta = 1.0 / (mttr_min * 60.0)
    n = s_max + 1
    fast = ehrenfest.transition_matrix(
        jnp.float64(s_max), jnp.float64(lam), jnp.float64(theta), jnp.float64(delta), n
    )
    oracle = ref.expm(jnp.asarray(bd_generator(s_max, lam, theta)) * delta)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(oracle), rtol=1e-8, atol=1e-11)


@settings(max_examples=12, deadline=None)
@given(s_max=st.integers(0, 20), pad_to=st.sampled_from([32, 64]), delta=st.floats(60.0, 1e5))
def test_padding_rows_inert(s_max, pad_to, delta):
    """With s_max < n, the live block must equal the unpadded computation."""
    lam, theta = 3e-6, 4e-4
    full = ehrenfest.transition_matrix(
        jnp.float64(s_max), jnp.float64(lam), jnp.float64(theta), jnp.float64(delta), pad_to
    )
    live = ehrenfest.transition_matrix(
        jnp.float64(s_max), jnp.float64(lam), jnp.float64(theta), jnp.float64(delta), s_max + 1
    )
    m = s_max + 1
    np.testing.assert_allclose(np.asarray(full)[:m, :m], np.asarray(live), rtol=1e-10, atol=1e-13)
    # Columns beyond s_max carry no probability in live rows.
    np.testing.assert_allclose(np.asarray(full)[:m, m:], 0.0, atol=1e-13)


@settings(max_examples=12, deadline=None)
@given(
    s_max=st.integers(0, 30),
    a=st.integers(1, 256),
    delta=st.floats(300.0, 2e5),
)
def test_chain_fast_matches_chain_probs(s_max, a, delta):
    """The fast artifact path must agree with the paper-faithful one."""
    lam, theta = 2.2e-6, 3.1e-4
    a_lam = a * lam
    n = s_max + 1
    fast_fn = model.make_chain_probs_fast(n)
    fast = fast_fn(
        jnp.float64(s_max), jnp.float64(lam), jnp.float64(theta),
        jnp.float64(a_lam), jnp.float64(delta),
    )
    slow = model.chain_probs(
        jnp.asarray(bd_generator(s_max, lam, theta)), jnp.float64(a_lam), jnp.float64(delta)
    )
    for name, f, s in zip(("q_delta", "q_up", "q_rec"), fast, slow):
        np.testing.assert_allclose(
            np.asarray(f), np.asarray(s), rtol=1e-7, atol=1e-10, err_msg=name
        )


def test_chain_fast_padded_block_decoupled():
    """Padding must not leak into the live block through the tridiag solve."""
    n, s_max = 16, 9
    lam, theta, a_lam, delta = 2e-6, 4e-4, 1e-4, 7200.0
    fast_fn = model.make_chain_probs_fast(n)
    padded = fast_fn(
        jnp.float64(s_max), jnp.float64(lam), jnp.float64(theta),
        jnp.float64(a_lam), jnp.float64(delta),
    )
    exact_fn = model.make_chain_probs_fast(s_max + 1)
    exact = exact_fn(
        jnp.float64(s_max), jnp.float64(lam), jnp.float64(theta),
        jnp.float64(a_lam), jnp.float64(delta),
    )
    m = s_max + 1
    for p, e in zip(padded, exact):
        np.testing.assert_allclose(np.asarray(p)[:m, :m], np.asarray(e), rtol=1e-9, atol=1e-12)


def test_spare_probs_limits():
    p_uu, p_du = ehrenfest.spare_probs(jnp.float64(1e-6), jnp.float64(1e-3), jnp.float64(0.0))
    assert abs(float(p_uu) - 1.0) < 1e-15
    assert abs(float(p_du)) < 1e-15


def test_aot_chain_fast_lowers_clean():
    from compile import aot

    text = aot.lower_chain_fast(8)
    assert "custom-call" not in text
    assert "f64[8,8]" in text
