"""AOT compile path: lower the Layer-2 model to HLO-text artifacts.

Run once by ``make artifacts`` (no-op when outputs are newer than inputs);
the rust coordinator loads the text with ``HloModuleProto::from_text_file``
and executes through the PJRT CPU client. HLO *text* -- NOT
``lowered.compile()`` / proto ``.serialize()`` -- is the interchange format:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

One artifact per size bucket n in BUCKETS:
  chain_probs_{n}.hlo.txt : (R[n,n], a_lambda, delta) -> (q_delta, q_up, q_rec)
  expm_{n}.hlo.txt        : (R[n,n], delta)           -> (expm(R delta),)
plus a manifest.json the rust runtime uses to discover buckets.

Usage: python -m compile.aot --out-dir ../artifacts [--buckets 8,16,...]
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Size buckets for padded birth-death chains (chain size = S+1 <= N).
# Power-of-two ladder keeps worst-case padding overhead at 2x rows.
BUCKETS = [8, 16, 32, 64, 128, 256, 512]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_chain_probs(n: int) -> str:
    mat = jax.ShapeDtypeStruct((n, n), jnp.float64)
    scalar = jax.ShapeDtypeStruct((), jnp.float64)
    return to_hlo_text(jax.jit(model.chain_probs).lower(mat, scalar, scalar))


def lower_expm(n: int) -> str:
    mat = jax.ShapeDtypeStruct((n, n), jnp.float64)
    scalar = jax.ShapeDtypeStruct((), jnp.float64)
    return to_hlo_text(jax.jit(model.expm_only).lower(mat, scalar))


def lower_chain_fast(n: int) -> str:
    scalar = jax.ShapeDtypeStruct((), jnp.float64)
    return to_hlo_text(
        jax.jit(model.make_chain_probs_fast(n)).lower(scalar, scalar, scalar, scalar, scalar)
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--buckets",
        default=",".join(str(b) for b in BUCKETS),
        help="comma-separated chain size buckets",
    )
    args = ap.parse_args()
    buckets = [int(b) for b in args.buckets.split(",") if b]

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"dtype": "f64", "chain_probs": {}, "chain_fast": {}, "expm": {}}
    for n in buckets:
        for name, lower in (
            ("chain_probs", lower_chain_probs),
            ("chain_fast", lower_chain_fast),
            ("expm", lower_expm),
        ):
            text = lower(n)
            fname = f"{name}_{n}.hlo.txt"
            path = os.path.join(args.out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            manifest[name][str(n)] = fname
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
