"""Layer-2 JAX model: transition-likelihood matrices for one birth-death chain.

The paper builds its malleable-application Markov model M^mall from N
birth-death spare-pool chains, one per possible active-processor count ``a``.
For each chain (generator ``R``, Eq. 1 of the paper) three matrices feed the
P^mall assembly (done by the rust coordinator):

  q_delta = expm(R * delta)
      spare evolution over the fixed recovery window delta = R + I + L
      (used for recovery -> up transitions, Eq. 2),

  q_up = integral_0^inf expm(R t) * a*lam*exp(-a*lam*t) dt
       = a*lam * (a*lam*I - R)^{-1}
      spare evolution at the moment an up state is exited by a failure of
      one of the ``a`` active processors (TTF-weighted, Eq. 3),

  q_rec = integral_0^delta expm(R t) * f dt,  f = a*lam*e^{-a*lam*t}/(1-e^{-a*lam*delta})
        = a*lam/(1 - e^{-a*lam*delta}) * (a*lam*I - R)^{-1} (I - e^{-a*lam*delta} expm(R delta))
      spare evolution at a failure *within* the recovery window (Eq. 3
      conditioned on tau < delta).

The resolvent closed forms replace the eigendecomposition route of Plank &
Thomason's MATLAB scripts: ``a*lam*I`` commutes with ``R``, so
``integral_0^delta e^{(R - a*lam*I)t} dt = (a*lam*I - R)^{-1}(I - e^{-a*lam*delta}e^{R delta})``
exactly. ``R`` is tridiagonal, so the resolvent is a Thomas solve
(kernels/tridiag.py) and the exponential is scaling-and-squaring over the
Layer-1 Pallas matmul (kernels/expm.py) -- everything lowers to pure HLO.

Shapes are static per AOT artifact: the rust runtime pads a chain of size
S+1 into the smallest bucket n >= S+1 with zero generator rows. Padding is
inert: zero rows make expm the identity and the resolvent diagonal 1/(a*lam)
on the pad block, so every q_* is exactly the identity there (verified by
python/tests/test_model.py::test_padding_inert and by rust proptests).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from .kernels import ehrenfest
from .kernels import expm as expm_k
from .kernels import tridiag


def chain_probs(r, a_lambda, delta):
    """Compute (q_delta, q_up, q_rec) for one padded birth-death generator.

    Args:
      r:        (n, n) f64 tridiagonal CTMC generator (rows sum to 0;
                padding rows all-zero).
      a_lambda: scalar f64, aggregate failure rate a * lambda of the active
                processors.
      delta:    scalar f64, recovery window R + I + L in seconds.

    Returns:
      Tuple of three (n, n) f64 row-stochastic matrices.
    """
    n = r.shape[0]
    eye = jnp.eye(n, dtype=r.dtype)

    q_delta = expm_k.expm(r * delta)

    # Resolvent solves: M = a*lam*I - R, tridiagonal and strictly
    # diagonally dominant (diag = a*lam + |offdiags|).
    dl, dd, du = tridiag.bands_from_dense(-r)
    dd = dd + a_lambda

    q_up = a_lambda * tridiag.solve(dl, dd, du, eye)

    decay = jnp.exp(-a_lambda * delta)
    denom = -jnp.expm1(-a_lambda * delta)  # 1 - e^{-a lam delta}, stable
    rhs = eye - decay * q_delta
    q_rec = (a_lambda / denom) * tridiag.solve(dl, dd, du, rhs)

    return q_delta, q_up, q_rec


def expm_only(r, delta):
    """Standalone ``expm(R * delta)`` entry point (perf-bench artifact)."""
    return expm_k.expm(r * delta)


def make_chain_probs_fast(n):
    """Fast-path chain matrices from the spare-pool parameterization.

    Returns a function of runtime scalars ``(s_max, lam, theta, a_lambda,
    delta)`` producing the same (q_delta, q_up, q_rec) tuple as
    ``chain_probs`` over a static (n, n) padded block, but via the
    closed-form Ehrenfest transition matrix (kernels/ehrenfest.py) --
    O(n^2) values instead of a scaling-and-squaring expm. One artifact per
    bucket serves every chain size <= n because ``s_max`` is a runtime
    input; the pad block rows/cols beyond s_max are inert for the rust
    consumer (it reads the top-left (s_max+1)^2 block).
    """

    def chain_probs_fast(s_max, lam, theta, a_lambda, delta):
        q_delta = ehrenfest.transition_matrix(s_max, lam, theta, delta, n)

        # Bands of M = a*lam*I - R, masked beyond s_max so the padding
        # rows decouple (fail/repair rates zero there).
        s = jnp.arange(n, dtype=jnp.float64)
        fail = jnp.where(s <= s_max, s * lam, 0.0)
        repair = jnp.where(s < s_max, (s_max - s) * theta, 0.0)
        dd = a_lambda + fail + repair
        dl = -fail  # dl[0] ignored by the solver
        du = -repair  # du[n-1] is zero by the mask for s_max <= n-1

        eye = jnp.eye(n, dtype=jnp.float64)
        q_up = a_lambda * tridiag.solve(dl, dd, du, eye)

        decay = jnp.exp(-a_lambda * delta)
        denom = -jnp.expm1(-a_lambda * delta)
        rhs = eye - decay * q_delta
        q_rec = (a_lambda / denom) * tridiag.solve(dl, dd, du, rhs)
        return q_delta, q_up, q_rec

    return chain_probs_fast
