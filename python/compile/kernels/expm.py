"""Matrix exponential by scaling-and-squaring with a Taylor core.

``expm(A) = (exp(A / 2^s))^(2^s)`` where ``s`` is chosen so that
``||A / 2^s||_inf <= THETA``; the scaled exponential is evaluated with a
Horner-form Taylor polynomial of fixed order. All heavy ops are matmuls
executed by the Layer-1 Pallas kernel (kernels/matmul_pallas.py), so the
whole routine lowers to pure HLO -- no LAPACK custom-calls, which the
xla_extension 0.5.1 CPU PJRT client could not run. This replaces the
Pade-13 ``expm`` (which needs a dense LU solve) used by MATLAB in the
paper's scripts; for CTMC generators scaled to ||A|| <= 0.25 the order-18
Taylor truncation error is ~0.25^19/19! ~ 1e-29, far below f64 roundoff.

The number of squarings is data dependent (||R * delta|| spans ~1e-3..1e5
across the paper's lambda/theta/delta ranges), so the squaring loop is a
``lax.while_loop`` with a dynamic trip count -- legal in AOT HLO.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax

from . import matmul_pallas

# Scale target for the Taylor core. Smaller THETA = more squarings but a
# shorter series; 0.25 with TAYLOR_ORDER=18 is far below f64 ulp.
THETA = 0.25
TAYLOR_ORDER = 18


def _taylor_exp(a_scaled, block):
    """Horner evaluation of sum_{i<=TAYLOR_ORDER} a^i / i! .

    T_m = I + a/m; T_{k} = I + (a @ T_{k+1}) / k  for k = m-1 .. 1.
    """
    n = a_scaled.shape[0]
    eye = jnp.eye(n, dtype=a_scaled.dtype)

    def body(i, t):
        # k runs TAYLOR_ORDER-1 ... 1 as i runs 0 ... TAYLOR_ORDER-2
        k = (TAYLOR_ORDER - 1) - i
        prod = matmul_pallas.matmul(a_scaled, t, block=block)
        return eye + prod / k.astype(a_scaled.dtype)

    t0 = eye + a_scaled / TAYLOR_ORDER
    return lax.fori_loop(0, TAYLOR_ORDER - 1, body, t0)


@functools.partial(jax.jit, static_argnames=("block",))
def expm(a, *, block: int = matmul_pallas.DEFAULT_BLOCK):
    """``expm(a)`` for a square f64 matrix, pure-HLO lowering."""
    a = jnp.asarray(a)
    norm = jnp.max(jnp.sum(jnp.abs(a), axis=1))  # ||a||_inf
    # Number of squarings: smallest s >= 0 with norm / 2^s <= THETA.
    s = jnp.ceil(jnp.log2(jnp.maximum(norm / THETA, 1.0))).astype(jnp.int32)
    scale = jnp.exp2(-s.astype(a.dtype))
    t = _taylor_exp(a * scale, block)

    def cond(carry):
        i, _ = carry
        return i < s

    def body(carry):
        i, m = carry
        return i + 1, matmul_pallas.matmul(m, m, block=block)

    _, result = lax.while_loop(cond, body, (jnp.int32(0), t))
    return result
