"""Tridiagonal (Thomas) solve with batched right-hand sides, via lax.scan.

The resolvent ``(a*lambda*I - R)^{-1}`` of the birth-death generator R is the
closed form of the paper's TTF-weighted transition integrals (Eq. 3 with
exponential f_tau; DESIGN.md section 3). R is tridiagonal, so the solve is a
Thomas forward/backward sweep -- O(n^2) for n right-hand sides, numerically
stable here because ``a*lambda*I - R`` is strictly (column/row) diagonally
dominant: diag = a*lambda + s*lambda + (S-s)*theta, off-diags sum to
s*lambda + (S-s)*theta.

Implemented as two ``lax.scan``s carrying whole RHS rows, so it lowers to
pure HLO (no LAPACK custom-calls) for AOT execution under the CPU PJRT
client.
"""

import jax
import jax.numpy as jnp
from jax import lax


@jax.jit
def solve(dl, dd, du, b):
    """Solve ``T x = b`` for tridiagonal ``T``.

    Args:
      dl: (n,) sub-diagonal; ``dl[0]`` ignored.
      dd: (n,) main diagonal.
      du: (n,) super-diagonal; ``du[n-1]`` ignored.
      b:  (n, m) right-hand sides (m solved simultaneously).

    Returns:
      (n, m) solution x.
    """
    n = dd.shape[0]

    # Forward sweep: eliminate the sub-diagonal.
    #   cp[i] = du[i] / (dd[i] - dl[i] * cp[i-1])
    #   bp[i] = (b[i] - dl[i] * bp[i-1]) / (dd[i] - dl[i] * cp[i-1])
    def fwd(carry, row):
        cp_prev, bp_prev = carry
        dl_i, dd_i, du_i, b_i = row
        denom = dd_i - dl_i * cp_prev
        cp_i = du_i / denom
        bp_i = (b_i - dl_i * bp_prev) / denom
        return (cp_i, bp_i), (cp_i, bp_i)

    cp0 = du[0] / dd[0]
    bp0 = b[0] / dd[0]
    (_, _), (cps, bps) = lax.scan(
        fwd,
        (cp0, bp0),
        (dl[1:], dd[1:], du[1:], b[1:]),
    )
    cps = jnp.concatenate([cp0[None], cps])
    bps = jnp.concatenate([bp0[None], bps])

    # Backward substitution: x[i] = bp[i] - cp[i] * x[i+1].
    def bwd(x_next, row):
        cp_i, bp_i = row
        x_i = bp_i - cp_i * x_next
        return x_i, x_i

    x_last = bps[n - 1]
    _, xs = lax.scan(bwd, x_last, (cps[: n - 1], bps[: n - 1]), reverse=True)
    return jnp.concatenate([xs, x_last[None]])


@jax.jit
def bands_from_dense(t):
    """Extract (dl, dd, du) bands from a dense tridiagonal matrix."""
    n = t.shape[0]
    dd = jnp.diagonal(t)
    dl = jnp.concatenate([jnp.zeros((1,), t.dtype), jnp.diagonal(t, -1)])
    du = jnp.concatenate([jnp.diagonal(t, 1), jnp.zeros((1,), t.dtype)])
    del n
    return dl, dd, du
