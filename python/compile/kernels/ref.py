"""Pure-jnp correctness oracles for the Layer-1 kernels.

These are the reference implementations the pytest suite compares the Pallas
kernels and the AOT-lowered model functions against. They may use LAPACK-
backed jnp.linalg / jax.scipy routines freely -- they run only at build/test
time in Python, never through the rust PJRT path.
"""

import jax.numpy as jnp
import jax.scipy.linalg as jsl


def matmul(x, y):
    """Oracle for kernels.matmul_pallas.matmul."""
    return jnp.dot(x, y, preferred_element_type=x.dtype)


def expm(a):
    """Oracle for kernels.expm.expm (SciPy-grade Pade implementation)."""
    return jsl.expm(a)


def tridiag_solve(dl, dd, du, b):
    """Oracle for kernels.tridiag.solve via dense jnp.linalg.solve."""
    n = dd.shape[0]
    t = jnp.diag(dd)
    t = t.at[jnp.arange(1, n), jnp.arange(n - 1)].set(dl[1:])
    t = t.at[jnp.arange(n - 1), jnp.arange(1, n)].set(du[: n - 1])
    return jnp.linalg.solve(t, b)


def chain_probs(r, a_lambda, delta):
    """Oracle for model.chain_probs (dense inverse / scipy expm).

    Returns (q_delta, q_up, q_rec); see python/compile/model.py for the
    derivation and DESIGN.md section 3 for the closed forms.
    """
    n = r.shape[0]
    eye = jnp.eye(n, dtype=r.dtype)
    q_delta = jsl.expm(r * delta)
    m = a_lambda * eye - r
    m_inv = jnp.linalg.inv(m)
    q_up = a_lambda * m_inv
    decay = jnp.exp(-a_lambda * delta)
    denom = -jnp.expm1(-a_lambda * delta)
    q_rec = (a_lambda / denom) * (m_inv @ (eye - decay * q_delta))
    return q_delta, q_up, q_rec
