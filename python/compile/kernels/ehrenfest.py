"""Closed-form spare-pool transition matrix (Ehrenfest fast path).

The birth-death generator of the paper's Eq. 1 describes S *independent*
two-state spares, so row s1 of ``expm(R * delta)`` is the pmf of

    Bin(s1, p_uu) + Bin(S - s1, p_du)

(see rust/src/markov/ehrenfest.rs for the derivation and the 2-state
closed forms). Here the full matrix is built as a *batched convolution*
of two binomial-pmf matrices -- O(n^2) values from O(n^3) vectorized work
that lowers to a single HLO Convolution op, replacing the
O(n^3 log ||R delta||) scaling-and-squaring ``expm`` on the AOT hot path.
The generic kernel (kernels/expm.py) remains the paper-faithful oracle;
python/tests/test_ehrenfest.py cross-checks the two.

``s_max`` is passed as a *runtime* scalar so one artifact per size bucket
serves every chain size <= bucket: rows and columns beyond ``s_max`` are
masked and the padding block is inert for the consumer (rust reads the
top-left (s_max+1)^2 block only).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax.scipy.special import gammaln


def spare_probs(lam, theta, delta):
    """2-state closed forms (p_uu, p_du) for window delta."""
    rho = lam + theta
    decay = jnp.exp(-rho * delta)
    p_stat = theta / rho
    return p_stat + (lam / rho) * decay, p_stat * (1.0 - decay)


def _binom_pmf_rows(counts, p, n):
    """Row i = pmf of Bin(counts[i], p) over support 0..n-1 (masked)."""
    k = jnp.arange(n, dtype=jnp.float64)[None, :]
    c = counts[:, None]
    valid = k <= c
    # Guard the log terms: where masked, inputs are clamped to safe values.
    p = jnp.clip(p, 1e-300, 1.0 - 1e-16)
    log_c = gammaln(c + 1.0) - gammaln(k + 1.0) - gammaln(jnp.maximum(c - k, 0.0) + 1.0)
    log_pmf = log_c + k * jnp.log(p) + (c - k) * jnp.log1p(-p)
    return jnp.where(valid, jnp.exp(log_pmf), 0.0)


def transition_matrix(s_max, lam, theta, delta, n):
    """Full ``expm(R * delta)`` over a padded (n, n) block.

    Args:
      s_max: runtime scalar (f64), actual spare count S <= n - 1.
      lam, theta, delta: runtime scalars.
      n: static padded size.

    Rows i <= S hold the true transition pmf; rows beyond are don't-care
    (the row for the clamped count), never read by the consumer.
    """
    p_uu, p_du = spare_probs(lam, theta, delta)
    i = jnp.minimum(jnp.arange(n, dtype=jnp.float64), s_max)
    up_counts = i
    down_counts = jnp.maximum(s_max - i, 0.0)
    u = _binom_pmf_rows(up_counts, p_uu, n)  # Bin(i, p_uu)
    v = _binom_pmf_rows(down_counts, p_du, n)  # Bin(S - i, p_du)

    # Row-wise convolution E[i, :] = (u[i] * v[i])[:n] via FFT: XLA CPU's
    # direct f64 Convolution op is naive-loop slow (~1 min at n = 256),
    # while the FFT lowers to the fast DUCC path. Probabilities are
    # clamped at 0 against fp ringing and renormalized to exact
    # stochasticity.
    m = 2 * n
    fu = jnp.fft.rfft(u, n=m, axis=1)
    fv = jnp.fft.rfft(v, n=m, axis=1)
    e = jnp.fft.irfft(fu * fv, n=m, axis=1)[:, :n]
    e = jnp.maximum(e, 0.0)
    return e / jnp.sum(e, axis=1, keepdims=True)
