"""Layer-1 Pallas kernel: tiled dense matmul.

This is the compute workhorse of model construction: the scaling-and-squaring
matrix exponential (kernels/expm.py) performs O(log ||R*delta||) squarings of
the birth-death generator, each of which is a dense n x n matmul. On a real
TPU this kernel tiles (bm, bk) x (bk, bn) blocks into VMEM and drives the
MXU; the BlockSpec index maps express the HBM<->VMEM schedule over the k
reduction. On this image we lower with ``interpret=True`` so the kernel
becomes plain HLO that the CPU PJRT client (xla_extension 0.5.1) can run --
see DESIGN.md section "Hardware-Adaptation".

The kernel is shape-polymorphic over square-ish sizes used by the chain
builder (8..512) and is validated against the pure-jnp oracle in ref.py by
python/tests/test_matmul_pallas.py (hypothesis sweeps shapes and dtypes).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block edge used when the operand is large enough; matrices smaller than
# the block are processed as a single tile. 64 keeps the f64 working set
# (3 tiles) at 3 * 64*64 * 8 B = 96 KiB -- comfortably inside a TPU core's
# VMEM budget and small enough that interpret-mode overhead stays low.
DEFAULT_BLOCK = 64


def _matmul_kernel(x_ref, y_ref, o_ref):
    """Accumulating tile kernel: o[i,j] += x[i,k] @ y[k,j].

    The k grid axis is innermost; the output tile is zero-initialised on the
    first k step and accumulated on the rest.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block",))
def matmul(x, y, *, block: int = DEFAULT_BLOCK):
    """Tiled Pallas matmul ``x @ y`` for 2-D operands.

    Requires ``x.shape = (m, k)``, ``y.shape = (k, n)``. Dimensions that are
    not multiples of ``block`` fall back to a single whole-array tile (the
    chain builder always passes power-of-two bucket sizes, so the tiled path
    is the common one).
    """
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {y.shape}")

    bm = block if m % block == 0 else m
    bk = block if k % block == 0 else k
    bn = block if n % block == 0 else n

    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(x, y)
